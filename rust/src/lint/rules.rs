//! The rule engine: tokenize stripped code lines and match the SIM00x
//! patterns. See the module docs in [`super`] for the rule table and
//! waiver syntax.
//!
//! Matching is token-based, not parser-based, so it is conservative by
//! construction: a field named like a hash container in another struct can
//! produce a false positive (waive it), and a hash container returned from
//! a function and iterated at the call site can slip through. Both edges
//! are acceptable — the rules exist to keep *this* tree clean, and the
//! meta-test pins the current tree at zero findings.

use std::collections::BTreeSet;

use super::strip::strip;
use super::Finding;

/// Modules whose iteration order feeds event scheduling, report assembly,
/// or f64 summation — SIM001 scope. `benches/` and `tests/` qualify
/// because their embedded baseline cores and assertions feed the same
/// determinism guarantees the crate sources do.
const ORDER_SENSITIVE: &[&str] = &[
    "sim/",
    "net/",
    "framework/",
    "ops/",
    "coordinator/",
    "sector/",
    "hadoop/",
    "transport/",
    "benches/",
    "tests/",
];

/// The flow/water-filling paths — SIM005 scope.
const FLOW_PATHS: &[&str] = &["net/flows.rs", "net/mod.rs", "transport/"];

/// Container methods whose visit order is the hasher's — SIM001 triggers.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// Ambient-randomness markers — SIM003 triggers.
const RANDOM_SOURCES: &[&str] =
    &["thread_rng", "from_entropy", "getrandom", "OsRng", "StdRng", "SmallRng", "RandomState"];

/// Print macros — SIM004 triggers outside entry points.
const PRINT_MACROS: &[&str] = &["println!", "eprintln!", "print!", "eprint!"];

/// Paths exempt from SIM006: `sim/par.rs` is the one module allowed to
/// spawn threads (the conservative parallel harness — determinism is its
/// whole contract), and `gmp/` drives *real* UDP sockets whose RX pumps
/// are real-world I/O threads that never touch simulated state.
const PAR_EXEMPT: &[&str] = &["sim/par.rs", "gmp/"];

/// Thread-spawn and ambient-parallelism markers — SIM006 triggers. Whole
/// identifiers (`rayon`, `crossbeam`, `JoinHandle`, `yield_now`) match on
/// word boundaries; the `thread::` forms match the path spelling, so a
/// simulation-side function named `spawn` does not trip the rule.
const PAR_PATHS: &[&str] = &["thread::spawn", "thread::Builder"];
const PAR_WORDS: &[&str] = &["rayon", "crossbeam", "JoinHandle", "yield_now"];

/// Ad-hoc trace-sink markers — SIM007 triggers in order-sensitive
/// modules. Span/instant emission must go through `trace::Recorder`
/// (ring-bounded, absorbed into the canonical merge); a raw
/// `Vec<TraceEvent>` or a `*_log` vector accumulated on the side
/// re-introduces exactly the unbounded, order-fragile logging the
/// recorder replaces. `trace/` itself is out of scope — the recorder's
/// own ring is the sanctioned sink.
const TRACE_SINK_WORDS: &[&str] = &["TraceEvent", "side_log", "event_log", "trace_log"];

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Num { float: bool },
    Punct(String),
}

fn is_p(t: &Tok, p: &str) -> bool {
    matches!(t, Tok::Punct(x) if x == p)
}

fn ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn lex(s: &str) -> Vec<Tok> {
    const TWO: &[&str] = &[
        "==", "!=", "::", "..", "<=", ">=", "->", "=>", "&&", "||", "+=", "-=", "*=", "/=", "<<",
        ">>",
    ];
    let b: Vec<char> = s.chars().collect();
    let n = b.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let st = i;
            while i < n && ident_char(b[i]) {
                i += 1;
            }
            out.push(Tok::Ident(b[st..i].iter().collect()));
            continue;
        }
        if c.is_ascii_digit() {
            out.push(lex_number(&b, &mut i));
            continue;
        }
        if i + 1 < n {
            let two: String = [c, b[i + 1]].iter().collect();
            if TWO.contains(&two.as_str()) {
                out.push(Tok::Punct(two));
                i += 2;
                continue;
            }
        }
        out.push(Tok::Punct(c.to_string()));
        i += 1;
    }
    out
}

/// Lex one numeric literal starting at `b[*i]` (an ASCII digit); advances
/// `*i` past it. `float` is true for literals with a fractional part, an
/// exponent, or an `f32`/`f64` suffix — never for `0..n` ranges or method
/// calls on integer literals.
fn lex_number(b: &[char], i: &mut usize) -> Tok {
    let n = b.len();
    let mut float = false;
    if b[*i] == '0' && *i + 1 < n && matches!(b[*i + 1], 'x' | 'b' | 'o') {
        *i += 2;
        while *i < n && (b[*i].is_ascii_alphanumeric() || b[*i] == '_') {
            *i += 1;
        }
        return Tok::Num { float: false };
    }
    while *i < n && (b[*i].is_ascii_digit() || b[*i] == '_') {
        *i += 1;
    }
    if *i + 1 < n && b[*i] == '.' && b[*i + 1].is_ascii_digit() {
        float = true;
        *i += 1;
        while *i < n && (b[*i].is_ascii_digit() || b[*i] == '_') {
            *i += 1;
        }
    }
    if *i < n && (b[*i] == 'e' || b[*i] == 'E') {
        let mut j = *i + 1;
        if j < n && (b[j] == '+' || b[j] == '-') {
            j += 1;
        }
        if j < n && b[j].is_ascii_digit() {
            float = true;
            *i = j;
            while *i < n && b[*i].is_ascii_digit() {
                *i += 1;
            }
        }
    }
    let st = *i;
    while *i < n && (b[*i].is_ascii_alphanumeric() || b[*i] == '_') {
        *i += 1;
    }
    if b[st..*i].starts_with(&['f']) {
        float = true;
    }
    Tok::Num { float }
}

/// True when `word` occurs in `code` with non-identifier boundaries.
fn contains_word(code: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = !code[..at].chars().next_back().is_some_and(ident_char);
        let after_ok = !code[at + word.len()..].chars().next().is_some_and(ident_char);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// True when print macro `mac` (including its `!`) occurs with a
/// non-identifier character before it (`eprintln!` must not match the
/// embedded `println!`).
fn contains_macro(code: &str, mac: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(mac) {
        let at = start + pos;
        if !code[..at].chars().next_back().is_some_and(ident_char) {
            return true;
        }
        start = at + mac.len();
    }
    false
}

/// Extract a waiver from a comment: `simlint: allow(SIMxxx) — reason`.
/// Returns `(rule, reason)`; an empty reason is the SIM000 case.
fn parse_waiver(comment: &str) -> Option<(String, String)> {
    let i = comment.find("simlint:")?;
    let rest = comment[i + "simlint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let digits_ok = rule.len() == 6 && rule[3..].chars().all(|c| c.is_ascii_digit());
    if !rule.starts_with("SIM") || !digits_ok {
        return None;
    }
    let reason = rest[close + 1..]
        .trim_start_matches(|c: char| c.is_whitespace() || matches!(c, '—' | '-' | '–' | ':'))
        .trim()
        .to_string();
    Some((rule, reason))
}

/// Register identifiers declared with a hash-ordered container type on
/// this line: `let [mut] name = HashMap::…`, `name: HashMap<…>` fields,
/// parameters, and annotated bindings (possibly behind `&`, `Rc<RefCell<…>>`
/// and similar wrappers — the nearest single colon to the left names the
/// binding). `use` imports contribute nothing (`::` is a distinct token).
fn collect_hash_names(toks: &[Tok], names: &mut BTreeSet<String>) {
    for (h, tok) in toks.iter().enumerate() {
        let Tok::Ident(t) = tok else { continue };
        if t != "HashMap" && t != "HashSet" {
            continue;
        }
        if matches!(toks.first(), Some(Tok::Ident(kw)) if kw == "let") {
            let k = if matches!(toks.get(1), Some(Tok::Ident(m)) if m == "mut") { 2 } else { 1 };
            if let Some(Tok::Ident(name)) = toks.get(k) {
                names.insert(name.clone());
                continue;
            }
        }
        for k in (0..h).rev() {
            if is_p(&toks[k], ":") {
                if k >= 1 {
                    if let Tok::Ident(name) = &toks[k - 1] {
                        names.insert(name.clone());
                    }
                }
                break;
            }
        }
    }
}

/// SIM001 violation messages in a logical line's tokens.
fn sim001_matches(toks: &[Tok], hash_names: &BTreeSet<String>) -> Vec<String> {
    let mut out = Vec::new();
    // `name.iter()` and friends, including across joined chain lines.
    for w in 1..toks.len() {
        if !is_p(&toks[w], ".") || !toks.get(w + 2).is_some_and(|t| is_p(t, "(")) {
            continue;
        }
        if let (Tok::Ident(recv), Some(Tok::Ident(meth))) = (&toks[w - 1], toks.get(w + 1)) {
            if ITER_METHODS.contains(&meth.as_str()) && hash_names.contains(recv) {
                out.push(format!("iteration over hash-ordered `{recv}.{meth}()`"));
            }
        }
    }
    // `for … in [&[mut]] path.to.name {`
    let mut saw_for = false;
    for (w, tok) in toks.iter().enumerate() {
        match tok {
            Tok::Ident(t) if t == "for" => saw_for = true,
            Tok::Ident(t) if t == "in" && saw_for => {
                saw_for = false;
                if let Some(name) = for_loop_target(toks, w + 1) {
                    if hash_names.contains(&name) {
                        out.push(format!("for-loop over hash-ordered `{name}`"));
                    }
                }
            }
            _ => {}
        }
    }
    out
}

/// After a `for … in`, parse `[&][mut] ident(.ident|.N)*` followed by `{`
/// and return the final path segment (the iterated container's name).
fn for_loop_target(toks: &[Tok], mut j: usize) -> Option<String> {
    if toks.get(j).is_some_and(|t| is_p(t, "&")) {
        j += 1;
    }
    if matches!(toks.get(j), Some(Tok::Ident(m)) if m == "mut") {
        j += 1;
    }
    let Some(Tok::Ident(first)) = toks.get(j) else { return None };
    let mut last = Some(first.clone());
    j += 1;
    while toks.get(j).is_some_and(|t| is_p(t, ".")) {
        match toks.get(j + 1) {
            Some(Tok::Ident(seg)) => last = Some(seg.clone()),
            Some(Tok::Num { .. }) => last = None, // tuple field: not a name
            _ => return None,
        }
        j += 2;
    }
    if toks.get(j).is_some_and(|t| is_p(t, "{")) {
        last
    } else {
        None
    }
}

/// SIM005 violation messages in a logical line's tokens: `==`/`!=` with a
/// float literal on either side.
fn sim005_matches(toks: &[Tok]) -> Vec<String> {
    let mut out = Vec::new();
    for (w, tok) in toks.iter().enumerate() {
        let Tok::Punct(p) = tok else { continue };
        if p != "==" && p != "!=" {
            continue;
        }
        let lhs = w >= 1 && matches!(&toks[w - 1], Tok::Num { float: true });
        let rhs = match toks.get(w + 1) {
            Some(Tok::Num { float }) => *float,
            Some(t) if is_p(t, "-") => {
                matches!(toks.get(w + 2), Some(Tok::Num { float: true }))
            }
            _ => false,
        };
        if lhs || rhs {
            out.push(format!("exact f64 `{p}` against a float literal in a flow path"));
        }
    }
    out
}

fn push_unique(out: &mut Vec<Finding>, f: Finding) {
    if !out.contains(&f) {
        out.push(f);
    }
}

/// Scan one file's source. `rel` is the path relative to the scanned root
/// with `/` separators; it selects which rule scopes apply.
pub fn scan_source(rel: &str, src: &str) -> Vec<Finding> {
    let stripped = strip(src);
    let order_sensitive = ORDER_SENSITIVE.iter().any(|p| rel.starts_with(*p));
    let flow_path = FLOW_PATHS.iter().any(|p| rel == *p || rel.starts_with(*p));
    // Benches are plain `fn main` programs (harness = false): printing a
    // report is their job, exactly like `main.rs` and `bin/`.
    let entry = rel == "main.rs" || rel.starts_with("bin/") || rel.starts_with("benches/");
    let par_exempt = PAR_EXEMPT.iter().any(|p| rel == *p || rel.starts_with(*p));

    let line_toks: Vec<Vec<Tok>> = stripped.code.iter().map(|l| lex(l)).collect();
    let mut hash_names: BTreeSet<String> = BTreeSet::new();
    for toks in &line_toks {
        collect_hash_names(toks, &mut hash_names);
    }

    let waivers: Vec<Option<(String, String)>> =
        stripped.comments.iter().map(|c| parse_waiver(c)).collect();

    // A finding spanning lines [start..=end] (0-based) is waived by a
    // matching waiver on any of its lines, or on a comment-only line
    // immediately above.
    let waived = |rule: &str, start: usize, end: usize| -> bool {
        let lo = start.saturating_sub(1);
        (lo..=end).any(|i| match waivers.get(i) {
            Some(Some((r, _))) => r == rule && (i >= start || stripped.code[i].trim().is_empty()),
            _ => false,
        })
    };

    let mut out: Vec<Finding> = Vec::new();
    let finding = |line: usize, rule: &'static str, message: String| Finding {
        file: rel.to_string(),
        line: line + 1,
        rule,
        message,
    };

    // SIM000: every waiver missing its justification, used or not. Not
    // itself waivable — the tree cannot pass with unexplained escapes.
    for (idx, w) in waivers.iter().enumerate() {
        if let Some((rule, reason)) = w {
            if reason.is_empty() {
                let msg = format!("waiver for {rule} has no justification");
                push_unique(&mut out, finding(idx, "SIM000", msg));
            }
        }
    }

    // Per-physical-line rules: SIM002 / SIM003 / SIM004.
    for (idx, code) in stripped.code.iter().enumerate() {
        let wall_clock = code.contains("Instant::now") || contains_word(code, "SystemTime");
        if wall_clock && !waived("SIM002", idx, idx) {
            let msg = "wall-clock read in simulation source".to_string();
            push_unique(&mut out, finding(idx, "SIM002", msg));
        }
        if let Some(tok) = RANDOM_SOURCES.iter().find(|t| contains_word(code, t)) {
            if !waived("SIM003", idx, idx) {
                let msg = format!("ambient randomness `{tok}` (use seeded util::rng::Rng)");
                push_unique(&mut out, finding(idx, "SIM003", msg));
            }
        }
        if !entry {
            if let Some(mac) = PRINT_MACROS.iter().find(|m| contains_macro(code, m)) {
                if !waived("SIM004", idx, idx) {
                    let msg = format!("`{mac}` outside a binary entry point");
                    push_unique(&mut out, finding(idx, "SIM004", msg));
                }
            }
        }
        if order_sensitive {
            if let Some(tok) = TRACE_SINK_WORDS.iter().find(|t| contains_word(code, t)) {
                if !waived("SIM007", idx, idx) {
                    let msg =
                        format!("ad-hoc trace sink `{tok}` (route spans through trace::Recorder)");
                    push_unique(&mut out, finding(idx, "SIM007", msg));
                }
            }
        }
        if !par_exempt {
            let tok = PAR_PATHS
                .iter()
                .find(|t| code.contains(*t))
                .or_else(|| PAR_WORDS.iter().find(|t| contains_word(code, t)));
            if let Some(tok) = tok {
                if !waived("SIM006", idx, idx) {
                    let msg = format!("`{tok}` outside sim/par.rs (ambient parallelism)");
                    push_unique(&mut out, finding(idx, "SIM006", msg));
                }
            }
        }
    }

    // Logical-line rules: SIM001 / SIM005. Method chains continued onto
    // following lines (leading `.`) are joined, so `map\n.iter()` cannot
    // hide from the receiver match.
    let mut i = 0usize;
    while i < stripped.code.len() {
        let mut end = i;
        while end + 1 < stripped.code.len() && stripped.code[end + 1].trim_start().starts_with('.')
        {
            end += 1;
        }
        let sim001_applies = order_sensitive && !hash_names.is_empty();
        if sim001_applies || flow_path {
            let mut toks: Vec<Tok> = Vec::new();
            for t in line_toks.iter().take(end + 1).skip(i) {
                toks.extend(t.iter().cloned());
            }
            if sim001_applies && !waived("SIM001", i, end) {
                for msg in sim001_matches(&toks, &hash_names) {
                    push_unique(&mut out, finding(i, "SIM001", msg));
                }
            }
            if flow_path && !waived("SIM005", i, end) {
                for msg in sim005_matches(&toks) {
                    push_unique(&mut out, finding(i, "SIM005", msg));
                }
            }
        }
        i = end + 1;
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn sim001_flags_hash_map_method_iteration() {
        let src = concat!(
            "use std::collections::HashMap;\n",
            "struct S { m: HashMap<u32, u32> }\n",
            "fn f(s: &S) -> usize { s.m.iter().count() }\n",
        );
        let fs = scan_source("net/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["SIM001"]);
        assert_eq!(fs[0].line, 3);
        assert!(fs[0].message.contains("m.iter()"));
    }

    #[test]
    fn sim001_flags_let_binding_and_keys() {
        let src = concat!(
            "fn f() {\n",
            "    let mut seen = HashMap::new();\n",
            "    seen.insert(1, 2);\n",
            "    let n = seen.keys().count();\n",
            "    let _ = n;\n",
            "}\n",
        );
        let fs = scan_source("coordinator/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["SIM001"]);
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn sim001_flags_for_loop_over_ref() {
        let src = concat!(
            "struct S { tracked: HashMap<u32, f64> }\n",
            "fn f(s: &S) {\n",
            "    for (k, v) in &s.tracked {\n",
            "        let _ = (k, v);\n",
            "    }\n",
            "}\n",
        );
        let fs = scan_source("ops/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["SIM001"]);
        assert_eq!(fs[0].line, 3);
    }

    #[test]
    fn sim001_flags_multiline_chain() {
        let src = concat!(
            "struct S { live: HashMap<u64, u32> }\n",
            "fn f(s: &S) -> usize {\n",
            "    s.live\n",
            "        .iter()\n",
            "        .count()\n",
            "}\n",
        );
        let fs = scan_source("framework/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["SIM001"]);
        assert_eq!(fs[0].line, 3, "finding anchors at the chain head");
    }

    #[test]
    fn sim001_ignores_btreemap_and_out_of_scope_modules() {
        let btree = concat!(
            "use std::collections::BTreeMap;\n",
            "struct S { m: BTreeMap<u32, u32> }\n",
            "fn f(s: &S) -> usize { s.m.iter().count() }\n",
        );
        assert!(scan_source("net/x.rs", btree).is_empty());
        let hash = concat!(
            "struct S { m: HashMap<u32, u32> }\n",
            "fn f(s: &S) -> usize { s.m.iter().count() }\n",
        );
        assert!(scan_source("util/x.rs", hash).is_empty(), "util/ is not order-sensitive");
    }

    #[test]
    fn sim001_keyed_access_is_fine() {
        let src = concat!(
            "struct S { m: HashMap<u32, u32> }\n",
            "fn f(s: &S) -> Option<&u32> { s.m.get(&1) }\n",
        );
        assert!(scan_source("sim/x.rs", src).is_empty());
    }

    #[test]
    fn waiver_on_same_line_suppresses() {
        let src = concat!(
            "struct S { m: HashMap<u32, u32> }\n",
            "fn f(s: &S) -> usize { s.m.iter().count() } ",
            "// simlint: allow(SIM001) — aggregated into an order-free sum\n",
        );
        assert!(scan_source("net/x.rs", src).is_empty());
    }

    #[test]
    fn waiver_on_line_above_suppresses() {
        let src = concat!(
            "struct S { m: HashMap<u32, u32> }\n",
            "fn f(s: &S) -> usize {\n",
            "    // simlint: allow(SIM001) — count is order-insensitive\n",
            "    s.m.iter().count()\n",
            "}\n",
        );
        assert!(scan_source("net/x.rs", src).is_empty());
    }

    #[test]
    fn unjustified_waiver_reports_sim000() {
        let src = concat!(
            "struct S { m: HashMap<u32, u32> }\n",
            "fn f(s: &S) -> usize { s.m.iter().count() } // simlint: allow(SIM001)\n",
        );
        let fs = scan_source("net/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["SIM000"], "finding suppressed, escape reported");
    }

    #[test]
    fn sim002_flags_wall_clock_but_not_strings_or_imports() {
        let fs = scan_source("util/x.rs", "fn f() { let t = Instant::now(); let _ = t; }\n");
        assert_eq!(rules_of(&fs), vec!["SIM002"]);
        assert!(scan_source("util/x.rs", "use std::time::Instant;\n").is_empty());
        assert!(scan_source("util/x.rs", "let s = \"Instant::now\";\n").is_empty());
    }

    #[test]
    fn sim002_waiver_with_reason_passes() {
        let src = concat!(
            "fn f() { let t = Instant::now(); let _ = t; } ",
            "// simlint: allow(SIM002) — real socket deadline\n",
        );
        assert!(scan_source("gmp/x.rs", src).is_empty());
    }

    #[test]
    fn sim003_flags_ambient_randomness() {
        let fs = scan_source("util/x.rs", "fn f() { let r = thread_rng(); let _ = r; }\n");
        assert_eq!(rules_of(&fs), vec!["SIM003"]);
        assert!(
            scan_source("util/x.rs", "fn f() { let r = my_thread_rng_like(); let _ = r; }\n")
                .is_empty(),
            "identifier boundaries respected"
        );
    }

    #[test]
    fn sim004_flags_prints_outside_entry_points() {
        let src = "fn f() { println!(); }\n";
        assert_eq!(rules_of(&scan_source("util/x.rs", src)), vec!["SIM004"]);
        assert!(scan_source("main.rs", src).is_empty());
        assert!(scan_source("bin/simlint.rs", src).is_empty());
        let eprint = "fn f() { eprintln!(); }\n";
        let fs = scan_source("ops/x.rs", eprint);
        assert_eq!(rules_of(&fs), vec!["SIM004"]);
        assert!(fs[0].message.contains("eprintln!"), "must not report the embedded println!");
    }

    #[test]
    fn benches_are_entry_points_but_still_order_sensitive() {
        // Printing is a bench's job…
        assert!(scan_source("benches/flow_scale.rs", "fn main() { println!(); }\n").is_empty());
        // …but hash-ordered iteration in an embedded baseline core is not.
        let src = concat!(
            "struct S { flows: HashMap<u64, f64> }\n",
            "fn f(s: &S) -> usize { s.flows.iter().count() }\n",
        );
        assert_eq!(rules_of(&scan_source("benches/flow_churn.rs", src)), vec!["SIM001"]);
        // Wall-clock reads still need a justified waiver, bench or not.
        let clock = "fn main() { let t = Instant::now(); let _ = t; }\n";
        assert_eq!(rules_of(&scan_source("benches/x.rs", clock)), vec!["SIM002"]);
    }

    #[test]
    fn tests_are_order_sensitive_and_not_entry_points() {
        let src = concat!(
            "fn f() {\n",
            "    let mut seen = HashMap::new();\n",
            "    seen.insert(1, 2);\n",
            "    for k in &seen {\n",
            "        let _ = k;\n",
            "    }\n",
            "}\n",
        );
        assert_eq!(rules_of(&scan_source("tests/determinism.rs", src)), vec!["SIM001"]);
        let print = "fn f() { eprintln!(\"skipping\"); }\n";
        assert_eq!(rules_of(&scan_source("tests/integration.rs", print)), vec!["SIM004"]);
    }

    #[test]
    fn sim005_flags_float_literal_compares_in_flow_paths_only() {
        let src = "fn f(x: f64) -> bool { x == 0.5 }\n";
        assert_eq!(rules_of(&scan_source("net/flows.rs", src)), vec!["SIM005"]);
        assert!(scan_source("net/topology.rs", src).is_empty(), "outside the flow path scope");
        assert_eq!(rules_of(&scan_source("transport/tcp.rs", src)), vec!["SIM005"]);
    }

    #[test]
    fn sim005_ignores_integers_tuples_and_ordered_compares() {
        assert!(scan_source("net/flows.rs", "fn f(x: u32) -> bool { x == 5 }\n").is_empty());
        assert!(scan_source("net/flows.rs", "fn f(a: (f64, u32), b: u32) -> bool { a.1 == b }\n")
            .is_empty());
        assert!(scan_source("net/flows.rs", "fn f(x: f64) -> bool { x <= 0.0 }\n").is_empty());
    }

    #[test]
    fn sim005_catches_negative_and_exponent_literals() {
        let fs = scan_source("net/flows.rs", "fn f(x: f64) -> bool { x != -1.5 }\n");
        assert_eq!(rules_of(&fs), vec!["SIM005"]);
        let fs = scan_source("net/flows.rs", "fn f(x: f64) -> bool { x == 1e-9 }\n");
        assert_eq!(rules_of(&fs), vec!["SIM005"]);
    }

    #[test]
    fn sim006_flags_thread_use_outside_sim_par() {
        let src = "fn f() { let h = std::thread::spawn(|| {}); h.join().unwrap(); }\n";
        assert_eq!(rules_of(&scan_source("coordinator/x.rs", src)), vec!["SIM006"]);
        assert!(scan_source("sim/par.rs", src).is_empty(), "the parallel harness is exempt");
        assert!(scan_source("gmp/endpoint.rs", src).is_empty(), "real-socket pumps are exempt");
    }

    #[test]
    fn sim006_flags_parallelism_crates_and_sync_markers() {
        let fs = scan_source("net/x.rs", "use rayon::prelude::*;\n");
        assert_eq!(rules_of(&fs), vec!["SIM006"]);
        let fs = scan_source("sim/engine.rs", "fn f() { std::thread::yield_now(); }\n");
        assert_eq!(rules_of(&fs), vec!["SIM006"]);
        assert!(
            scan_source("net/x.rs", "fn crossbeam_like() {}\n").is_empty(),
            "identifier boundaries respected"
        );
        assert!(
            scan_source("benches/x.rs", "fn f(spawn: u32) -> u32 { spawn }\n").is_empty(),
            "a simulation-side `spawn` name is fine"
        );
    }

    #[test]
    fn sim006_waiver_with_reason_passes() {
        let src = concat!(
            "fn f() {\n",
            "    // simlint: allow(SIM006) — measurement thread outside the simulation\n",
            "    let h = std::thread::spawn(|| {});\n",
            "    h.join().unwrap();\n",
            "}\n",
        );
        assert!(scan_source("util/x.rs", src).is_empty());
    }

    #[test]
    fn sim007_flags_adhoc_trace_sinks_in_order_sensitive_modules() {
        let field = "struct S { event_log: Vec<u32> }\n";
        assert_eq!(rules_of(&scan_source("sim/x.rs", field)), vec!["SIM007"]);
        let vec_ty = "fn f() { let mut buf: Vec<TraceEvent> = Vec::new(); buf.clear(); }\n";
        assert_eq!(rules_of(&scan_source("coordinator/x.rs", vec_ty)), vec!["SIM007"]);
        assert_eq!(rules_of(&scan_source("tests/determinism.rs", vec_ty)), vec!["SIM007"]);
        // trace/ is not order-sensitive: the recorder's ring IS the sink.
        assert!(scan_source("trace/mod.rs", vec_ty).is_empty());
        assert!(scan_source("util/x.rs", field).is_empty(), "util/ out of scope");
        assert!(
            scan_source("sim/x.rs", "fn f(my_event_logger: u32) { let _ = my_event_logger; }\n")
                .is_empty(),
            "identifier boundaries respected"
        );
    }

    #[test]
    fn sim007_waiver_with_reason_passes() {
        let src = concat!(
            "fn f() {\n",
            "    // simlint: allow(SIM007) — bounded debug buffer, never merged into a report\n",
            "    let mut event_log: Vec<u32> = Vec::new();\n",
            "    event_log.clear(); ",
            "// simlint: allow(SIM007) — bounded debug buffer, never merged into a report\n",
            "}\n",
        );
        assert!(scan_source("ops/x.rs", src).is_empty());
    }

    #[test]
    fn waiver_parser_variants() {
        let (r, why) = parse_waiver("// simlint: allow(SIM001) — provably order-free").unwrap();
        assert_eq!(r, "SIM001");
        assert_eq!(why, "provably order-free");
        let (_, why) = parse_waiver("// simlint: allow(SIM002)").unwrap();
        assert!(why.is_empty());
        assert!(parse_waiver("// simlint: allow(BOGUS1)").is_none());
        assert!(parse_waiver("// plain comment").is_none());
    }
}

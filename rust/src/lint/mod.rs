//! # simlint — determinism hygiene for the simulation core
//!
//! A dependency-free static-analysis pass over `rust/src/**`,
//! `rust/benches/**`, and `rust/tests/**` that enforces the properties
//! every number in this repo rests on: runs replay bit-identically from a
//! seed, and nothing outside the seeded [`crate::util::rng::Rng`] or the
//! virtual clock can perturb them. The offline build has no crates.io
//! access, so the scanner is hand-rolled: [`strip`] splits each line into
//! code and comment channels, and [`rules`] matches token patterns
//! against the code channel.
//!
//! ## Rules
//!
//! | Rule   | Scope                         | What it rejects |
//! |--------|-------------------------------|-----------------|
//! | SIM001 | order-sensitive modules¹      | iteration over hash-ordered containers (`.iter()`, `.keys()`, `.values()`, `.drain()`, `for … in &map`, …) |
//! | SIM002 | everything scanned            | wall-clock reads (`Instant::now`, `SystemTime`) |
//! | SIM003 | everything scanned            | ambient randomness (`thread_rng`, `from_entropy`, `RandomState`, …) — draws go through the seeded `util::rng::Rng` |
//! | SIM004 | all but entry points²         | `println!`/`eprintln!`/`print!`/`eprint!` outside binary entry points |
//! | SIM005 | flow/water-filling paths³     | exact `f64` `==`/`!=` against float literals |
//! | SIM006 | all but `sim/par.rs`, `gmp/`⁴ | thread spawns and parallelism crates (`thread::spawn`, `thread::Builder`, `rayon`, `crossbeam`, `JoinHandle`, `yield_now`) |
//! | SIM007 | order-sensitive modules¹      | ad-hoc trace sinks (`Vec<TraceEvent>`, `side_log`/`event_log`/`trace_log` accumulators) — spans go through `trace::Recorder`⁵ |
//! | SIM000 | everywhere                    | a waiver comment with no justification (not waivable) |
//!
//! ¹ `sim/`, `net/`, `framework/`, `ops/`, `coordinator/`, `sector/`,
//!   `hadoop/`, `transport/` — modules whose iteration order feeds event
//!   scheduling, report assembly, or f64 summation — plus `benches/` and
//!   `tests/`, whose embedded baseline cores and assertions feed the same
//!   guarantees. Wall-clock reads in benches (the speedup measurements
//!   themselves) carry per-line waivers: the clock may time a run, never
//!   steer one.
//! ² `main.rs`, `bin/`, and `benches/` — benches are plain `fn main`
//!   programs whose printed report is their product.
//! ³ `net/flows.rs`, `net/mod.rs`, `transport/`.
//! ⁴ Ambient parallelism is a determinism hazard: any thread that touches
//!   simulated state races the event order. [`crate::sim::par`] is the one
//!   sanctioned harness (its lookahead protocol *is* the determinism
//!   argument), and `gmp/` pumps real UDP sockets on I/O threads that
//!   never see simulated state.
//! ⁵ The recorder is ring-bounded and absorbed into the canonical
//!   `(time, domain, shard-order)` merge; a raw event vector on the side
//!   is unbounded and replays in whatever order the module mutated it.
//!   `trace/` itself is out of scope — the ring is the sanctioned sink —
//!   and the profiler's pump-boundary wall reads are covered by the
//!   existing per-line SIM002 waivers, not by SIM007.
//!
//! ## Waivers
//!
//! A finding is suppressed by a justified waiver on the same line, or on a
//! comment-only line immediately above:
//!
//! ```text
//! let now = Instant::now(); // simlint: allow(SIM002) — real socket deadline, outside simulated time
//! ```
//!
//! The justification text after the rule id is mandatory: `allow(SIMxxx)`
//! with nothing after it still suppresses the original finding but reports
//! `SIM000`, so the tree cannot pass with unexplained escapes.
//!
//! ## Usage
//!
//! ```text
//! cargo run --release --bin simlint            # human-readable, exit 1 on findings
//! cargo run --release --bin simlint -- --json  # machine-readable report
//! cargo run --release --bin simlint -- <dir>   # scan a different root
//! ```

pub mod rules;
pub mod strip;

use std::path::Path;

use crate::util::json::{obj, Json};

/// One rule violation (or SIM000 waiver problem) at a source location.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id, e.g. `"SIM001"`.
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// Rule ids with one-line summaries (the `--json` report embeds them, and
/// the binary's `--help` prints them).
pub const RULES: &[(&str, &str)] = &[
    ("SIM000", "waiver without a justification"),
    ("SIM001", "iteration over a hash-ordered container in an order-sensitive module"),
    ("SIM002", "wall-clock read (Instant::now / SystemTime) in simulation source"),
    ("SIM003", "ambient randomness; all draws go through the seeded util::rng::Rng"),
    ("SIM004", "print to stdout/stderr outside a binary entry point"),
    ("SIM005", "exact f64 ==/!= comparison in a flow/water-filling path"),
    ("SIM006", "thread spawn or parallelism crate outside sim/par.rs"),
    ("SIM007", "ad-hoc trace event side-log outside trace::Recorder in an order-sensitive module"),
];

/// Scan every `.rs` file under `root`, visiting directories and files in
/// sorted order so the report is stable across platforms. Findings come
/// back sorted by `(file, line, rule)`.
pub fn scan_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    scan_tree_prefixed(root, "")
}

/// [`scan_tree`] with every relative path prefixed by `prefix/` — the
/// scope rules key off the prefix (`benches/…`, `tests/…`).
fn scan_tree_prefixed(root: &Path, prefix: &str) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let mut rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        if !prefix.is_empty() {
            rel = format!("{prefix}/{rel}");
        }
        findings.extend(rules::scan_source(&rel, &src));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(findings)
}

/// Scan a whole crate: `src/` (unprefixed, so module scopes like `net/`
/// resolve as before) plus `benches/` and `tests/` under their own
/// prefixes. Missing roots are skipped — a crate without benches is fine.
pub fn scan_crate(crate_root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut findings = scan_tree(&crate_root.join("src"))?;
    for extra in ["benches", "tests"] {
        let dir = crate_root.join(extra);
        if dir.is_dir() {
            findings.extend(scan_tree_prefixed(&dir, extra)?);
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The machine-readable report for `simlint --json`: deterministic (the
/// crate's [`Json`] objects are BTreeMap-backed) and self-describing.
pub fn report_json(findings: &[Finding]) -> Json {
    obj(vec![
        ("tool", Json::Str("simlint".into())),
        ("clean", Json::Bool(findings.is_empty())),
        (
            "rules",
            Json::Obj(
                RULES
                    .iter()
                    .map(|(id, desc)| (id.to_string(), Json::Str(desc.to_string())))
                    .collect(),
            ),
        ),
        (
            "findings",
            Json::Arr(
                findings
                    .iter()
                    .map(|f| {
                        obj(vec![
                            ("file", Json::Str(f.file.clone())),
                            ("line", Json::Num(f.line as f64)),
                            ("rule", Json::Str(f.rule.to_string())),
                            ("message", Json::Str(f.message.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The meta-test: the crate's own sources, benches, and integration
    /// tests must lint clean. Any rule violation introduced anywhere in
    /// the crate fails this test before it ever reaches CI's dedicated
    /// simlint step.
    #[test]
    fn tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = scan_crate(root).expect("scan failed");
        assert!(
            findings.is_empty(),
            "simlint findings in tree:\n{}",
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    /// Fixture coverage for the crate-level scan: the `benches/` and
    /// `tests/` roots are scanned under their prefixes (so their scope
    /// rules apply) and a crate without those roots scans clean.
    #[test]
    fn scan_crate_prefixes_extra_roots() {
        let fixture = std::env::temp_dir()
            .join(format!("simlint-fixture-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&fixture);
        for d in ["src", "benches", "tests"] {
            std::fs::create_dir_all(fixture.join(d)).expect("fixture dirs");
        }
        // src: clean. benches: a print (allowed — entry point) and a
        // wall-clock read (flagged). tests: a print (flagged).
        std::fs::write(fixture.join("src/lib.rs"), "pub fn ok() {}\n").unwrap();
        std::fs::write(
            fixture.join("benches/b.rs"),
            "fn main() { println!(); let t = Instant::now(); let _ = t; }\n",
        )
        .unwrap();
        std::fs::write(fixture.join("tests/t.rs"), "fn f() { println!(); }\n").unwrap();
        let findings = scan_crate(&fixture).expect("fixture scan");
        let got: Vec<(&str, &str)> =
            findings.iter().map(|f| (f.file.as_str(), f.rule)).collect();
        assert_eq!(got, vec![("benches/b.rs", "SIM002"), ("tests/t.rs", "SIM004")]);

        // A crate with only src/ scans without error.
        std::fs::remove_dir_all(fixture.join("benches")).unwrap();
        std::fs::remove_dir_all(fixture.join("tests")).unwrap();
        assert!(scan_crate(&fixture).expect("src-only scan").is_empty());
        let _ = std::fs::remove_dir_all(&fixture);
    }

    #[test]
    fn report_json_shape() {
        let fs = vec![Finding {
            file: "net/x.rs".into(),
            line: 3,
            rule: "SIM001",
            message: "iteration over hash-ordered `m.iter()`".into(),
        }];
        let j = report_json(&fs);
        assert_eq!(j.get("clean"), Some(&Json::Bool(false)));
        let parsed = Json::parse(&j.to_string()).expect("round-trip");
        assert_eq!(parsed, j);
        let empty = report_json(&[]);
        assert_eq!(empty.get("clean"), Some(&Json::Bool(true)));
    }
}

//! Comment/string stripping for the lint scanner.
//!
//! `simlint` has no parser — it works on source text — so before any rule
//! runs, each line is split into its *code* part (string and char literal
//! contents blanked, comments removed) and its *comment* part (where
//! waivers live). A small state machine carries block-comment and string
//! state across lines, so multi-line strings (including raw strings) never
//! leak their contents into the code channel. Raw strings are handled
//! crudely (terminated at the first `"`), which is sufficient for this
//! crate's sources; the meta-test in [`super`] guards against drift.

/// One file split line-by-line into code and comment channels.
pub struct Stripped {
    /// Per-line code with literals blanked and comments removed.
    pub code: Vec<String>,
    /// Per-line comment text (line comments only; block comment bodies
    /// are discarded — waivers must use `//` comments).
    pub comments: Vec<String>,
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    Block,
    Str,
    RawStr,
}

/// Strip a whole source file.
pub fn strip(src: &str) -> Stripped {
    let mut code = Vec::new();
    let mut comments = Vec::new();
    let mut state = State::Code;
    for line in src.lines() {
        let (c, m, next) = strip_line(line, state);
        code.push(c);
        comments.push(m);
        state = next;
    }
    Stripped { code, comments }
}

/// Strip one line, threading the lexer state across line boundaries.
fn strip_line(line: &str, start: State) -> (String, String, State) {
    let b: Vec<char> = line.chars().collect();
    let n = b.len();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0usize;
    let mut state = start;
    while i < n {
        let c = b[i];
        let nxt = if i + 1 < n { b[i + 1] } else { '\0' };
        match state {
            State::Code => {
                if c == '/' && nxt == '/' {
                    comment.extend(&b[i..]);
                    break;
                }
                if c == '/' && nxt == '*' {
                    state = State::Block;
                    i += 2;
                    continue;
                }
                if c == '"' {
                    // `r"…"` / `r#"…"#`: no escapes; ends at the next quote.
                    let raw = i > 0 && (b[i - 1] == 'r' || b[i - 1] == '#');
                    state = if raw { State::RawStr } else { State::Str };
                    code.push('"');
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    // Char literal ('x', '\n') vs lifetime ('a).
                    if nxt == '\\' && i + 3 < n && b[i + 3] == '\'' {
                        code.push(' ');
                        i += 4;
                        continue;
                    }
                    if i + 2 < n && b[i + 2] == '\'' {
                        code.push(' ');
                        i += 3;
                        continue;
                    }
                    code.push(c);
                    i += 1;
                    continue;
                }
                code.push(c);
                i += 1;
            }
            State::Block => {
                if c == '*' && nxt == '/' {
                    state = State::Code;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    state = State::Code;
                    code.push('"');
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr => {
                if c == '"' {
                    state = State::Code;
                    code.push('"');
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    (code, comment, state)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comment_split() {
        let s = strip("let x = 1; // trailing note");
        assert_eq!(s.code[0], "let x = 1; ");
        assert_eq!(s.comments[0], "// trailing note");
    }

    #[test]
    fn string_contents_blanked() {
        let s = strip("let s = \"Instant::now inside a string\";");
        assert_eq!(s.code[0], "let s = \"\";");
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        let s = strip("let s = \"a\\\"b // not a comment\"; let y = 2;");
        assert!(s.code[0].contains("let y = 2;"));
        assert!(s.comments[0].is_empty());
    }

    #[test]
    fn block_comment_spans_lines() {
        let s = strip("a /* start\nstill hidden dot iter\nend */ b");
        assert_eq!(s.code[0], "a ");
        assert_eq!(s.code[1], "");
        assert_eq!(s.code[2], " b");
    }

    #[test]
    fn multiline_string_spans_lines() {
        let s = strip("let s = \"first\nsecond hidden line\nthird\"; tail();");
        assert_eq!(s.code[1], "");
        assert!(s.code[2].contains("tail();"));
    }

    #[test]
    fn char_literal_and_lifetime() {
        let s = strip("let c = '\"'; fn f<'a>(x: &'a str) {}");
        // The quote inside the char literal must not open a string.
        assert!(s.code[0].contains("fn f<'a>"));
    }

    #[test]
    fn comment_slashes_inside_string_ignored() {
        let s = strip("let url = \"http://example.com\"; let z = 3;");
        assert!(s.code[0].contains("let z = 3;"));
        assert!(s.comments[0].is_empty());
    }
}

//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! This is the L3↔L2 seam. `make artifacts` runs Python exactly once,
//! lowering the MalStone dataflow (JAX) and its Pallas histogram kernel to
//! **HLO text** (xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos; the text parser reassigns ids). With the `pjrt` cargo feature,
//! this module loads those files with the `xla` crate's PJRT CPU client,
//! compiles them once, and executes them from the Sphere hot path — Python
//! is never on the request path. Without the feature (the offline build
//! cannot fetch the `xla` crate), [`MalstoneKernels::load`] returns an
//! error and every consumer degrades to the pure-Rust aggregation path.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Runtime error (the offline build carries no `anyhow`).
#[derive(Debug, Clone)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn msg(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime seam.
pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Artifact geometry, read from `artifacts/meta.json` (written by aot.py;
/// must match python/compile/model.py).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub num_sites: usize,
    pub num_weeks: usize,
    pub tile: usize,
    pub batch: usize,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let raw = std::fs::read_to_string(&path)
            .map_err(|e| err(format!("reading {} — run `make artifacts`: {e}", path.display())))?;
        let j = Json::parse(&raw).map_err(|e| err(format!("meta.json: {e}")))?;
        let get = |k: &str| {
            j.get(k).and_then(Json::as_u64).ok_or_else(|| err(format!("meta.json missing {k}")))
        };
        Ok(ArtifactMeta {
            num_sites: get("num_sites")? as usize,
            num_weeks: get("num_weeks")? as usize,
            tile: get("tile")? as usize,
            batch: get("batch")? as usize,
        })
    }
}

/// The `(num_sites, num_weeks)` geometry python/compile/model.py bakes
/// into the artifacts — the fallback consumers use when no artifacts
/// are available (keep in sync with `NUM_SITES`/`NUM_WEEKS` there).
pub const DEFAULT_GEOMETRY: (u32, u32) = (256, 64);

/// Default artifact directory: `$OCT_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("OCT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::cell::RefCell;
    use std::path::Path;
    use std::rc::Rc;

    use crate::malstone::join::{to_kernel_arrays, JoinedRecord};
    use crate::malstone::oracle::MalstoneResult;

    use super::{err, ArtifactMeta, Result};

    /// The three compiled executables plus their geometry.
    pub struct MalstoneKernels {
        client: xla::PjRtClient,
        hist: xla::PjRtLoadedExecutable,
        ratio_a: xla::PjRtLoadedExecutable,
        ratio_b: xla::PjRtLoadedExecutable,
        pub meta: ArtifactMeta,
        /// Executions performed (hot-path metric).
        pub hist_calls: RefCell<u64>,
    }

    impl MalstoneKernels {
        /// Load and compile all artifacts from `dir`.
        pub fn load(dir: &Path) -> Result<Rc<MalstoneKernels>> {
            let meta = ArtifactMeta::load(dir)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| err(format!("PJRT cpu client: {e:?}")))?;
            let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| err("non-utf8 path"))?,
                )
                .map_err(|e| err(format!("loading {}: {e:?}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client.compile(&comp).map_err(|e| err(format!("compiling {name}: {e:?}")))
            };
            Ok(Rc::new(MalstoneKernels {
                hist: compile("malstone_hist")?,
                ratio_a: compile("malstone_ratio_a")?,
                ratio_b: compile("malstone_ratio_b")?,
                client,
                meta,
                hist_calls: RefCell::new(0),
            }))
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Histogram one padded batch (exactly `meta.batch` records).
        fn hist_batch(
            &self,
            site: &[i32],
            week: &[i32],
            marked: &[f32],
        ) -> Result<(Vec<f32>, Vec<f32>)> {
            assert_eq!(site.len(), self.meta.batch);
            let s = xla::Literal::vec1(site);
            let w = xla::Literal::vec1(week);
            let m = xla::Literal::vec1(marked);
            let result = self
                .hist
                .execute::<xla::Literal>(&[s, w, m])
                .map_err(|e| err(format!("hist execute: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("hist fetch: {e:?}")))?;
            *self.hist_calls.borrow_mut() += 1;
            // aot.py lowers with return_tuple=True: (comp, tot).
            let (comp_l, tot_l) =
                result.to_tuple2().map_err(|e| err(format!("hist tuple: {e:?}")))?;
            let comp = comp_l.to_vec::<f32>().map_err(|e| err(format!("comp vec: {e:?}")))?;
            let tot = tot_l.to_vec::<f32>().map_err(|e| err(format!("tot vec: {e:?}")))?;
            Ok((comp, tot))
        }

        /// Histogram an arbitrary number of joined records: batches through
        /// the compiled kernel and sums partial planes in Rust (the same f32
        /// merge the Sphere master performs across workers).
        pub fn hist(&self, joined: &[JoinedRecord]) -> Result<MalstoneResult> {
            let (site, week, marked) = to_kernel_arrays(joined, self.meta.batch);
            let mut out = MalstoneResult::zero(self.meta.num_sites, self.meta.num_weeks);
            for i in (0..site.len()).step_by(self.meta.batch) {
                let end = i + self.meta.batch;
                let (c, t) = self.hist_batch(&site[i..end], &week[i..end], &marked[i..end])?;
                for (a, b) in out.comp.iter_mut().zip(&c) {
                    *a += *b as f64;
                }
                for (a, b) in out.tot.iter_mut().zip(&t) {
                    *a += *b as f64;
                }
            }
            Ok(out)
        }

        fn ratio(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            planes: &MalstoneResult,
        ) -> Result<Vec<f32>> {
            let comp: Vec<f32> = planes.comp.iter().map(|&x| x as f32).collect();
            let tot: Vec<f32> = planes.tot.iter().map(|&x| x as f32).collect();
            let dims = [self.meta.num_sites, self.meta.num_weeks];
            let c = xla::Literal::vec1(&comp)
                .reshape(&[dims[0] as i64, dims[1] as i64])
                .map_err(|e| err(format!("reshape: {e:?}")))?;
            let t = xla::Literal::vec1(&tot)
                .reshape(&[dims[0] as i64, dims[1] as i64])
                .map_err(|e| err(format!("reshape: {e:?}")))?;
            let result = exe
                .execute::<xla::Literal>(&[c, t])
                .map_err(|e| err(format!("ratio execute: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("ratio fetch: {e:?}")))?;
            let out = result.to_tuple1().map_err(|e| err(format!("ratio tuple: {e:?}")))?;
            out.to_vec::<f32>().map_err(|e| err(format!("ratio vec: {e:?}")))
        }

        /// MalStone-A ratios (`[num_sites]`) via the compiled graph.
        pub fn ratio_a(&self, planes: &MalstoneResult) -> Result<Vec<f32>> {
            self.ratio(&self.ratio_a, planes)
        }

        /// MalStone-B cumulative ratio series (`[num_sites × num_weeks]`).
        pub fn ratio_b(&self, planes: &MalstoneResult) -> Result<Vec<f32>> {
            self.ratio(&self.ratio_b, planes)
        }

        /// A stage-2 aggregator closure for `sector::sphere::
        /// execute_malstone_with` — the three-layer hot path.
        pub fn aggregator(
            self: &Rc<Self>,
        ) -> impl FnMut(&[JoinedRecord], u32, u32) -> MalstoneResult + use<> {
            let k = self.clone();
            move |joined, num_sites, num_weeks| {
                assert_eq!(
                    (num_sites as usize, num_weeks as usize),
                    (k.meta.num_sites, k.meta.num_weeks),
                    "aggregator geometry mismatch"
                );
                k.hist(joined).expect("PJRT hist execution failed")
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::MalstoneKernels;

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use std::cell::RefCell;
    use std::path::Path;
    use std::rc::Rc;

    use crate::malstone::join::JoinedRecord;
    use crate::malstone::oracle::MalstoneResult;

    use super::{err, ArtifactMeta, Result};

    const DISABLED: &str = "oct was built without the `pjrt` feature; rebuild with \
         `--features pjrt` (and add the `xla` dependency to rust/Cargo.toml) \
         to execute AOT artifacts";

    /// Stub kernels: same surface as the PJRT-backed type, but `load`
    /// always fails so callers fall back to the pure-Rust path.
    pub struct MalstoneKernels {
        pub meta: ArtifactMeta,
        /// Executions performed (always zero on the stub).
        pub hist_calls: RefCell<u64>,
    }

    impl MalstoneKernels {
        /// Validates the artifact metadata, then reports the missing
        /// feature (artifact problems surface first for better errors).
        pub fn load(dir: &Path) -> Result<Rc<MalstoneKernels>> {
            ArtifactMeta::load(dir)?;
            Err(err(DISABLED))
        }

        pub fn platform(&self) -> String {
            "unavailable (pjrt feature disabled)".to_string()
        }

        pub fn hist(&self, _joined: &[JoinedRecord]) -> Result<MalstoneResult> {
            Err(err(DISABLED))
        }

        pub fn ratio_a(&self, _planes: &MalstoneResult) -> Result<Vec<f32>> {
            Err(err(DISABLED))
        }

        pub fn ratio_b(&self, _planes: &MalstoneResult) -> Result<Vec<f32>> {
            Err(err(DISABLED))
        }

        /// Matches the PJRT signature; unreachable because `load` never
        /// constructs a stub.
        pub fn aggregator(
            self: &Rc<Self>,
        ) -> impl FnMut(&[JoinedRecord], u32, u32) -> MalstoneResult + use<> {
            |_joined, _num_sites, _num_weeks| unreachable!("{}", DISABLED)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_impl::MalstoneKernels;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let dir = default_artifact_dir();
        if !dir.join("meta.json").exists() {
            return;
        }
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.batch, m.tile * (m.batch / m.tile));
        assert!(m.num_sites > 0 && m.num_weeks > 0);
    }

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let e = ArtifactMeta::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
        assert!(!e.msg().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_missing_feature_when_artifacts_exist() {
        let dir = default_artifact_dir();
        if !dir.join("meta.json").exists() {
            return;
        }
        let e = MalstoneKernels::load(&dir).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod pjrt_tests {
    use std::rc::Rc;

    use super::*;
    use crate::malstone::join::{bucketize, compromise_table, JoinedRecord};
    use crate::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
    use crate::malstone::oracle::MalstoneResult;
    use crate::util::Rng;

    fn kernels() -> Option<Rc<MalstoneKernels>> {
        let dir = default_artifact_dir();
        if !dir.join("meta.json").exists() {
            eprintln!("skipping PJRT test: artifacts not built (run `make artifacts`)"); // simlint: allow(SIM004) — test-skip notice in a feature-gated test, not simulation output
            return None;
        }
        Some(MalstoneKernels::load(&dir).expect("artifact load"))
    }

    #[test]
    fn hist_matches_oracle_on_random_records() {
        let Some(k) = kernels() else { return };
        let mut rng = Rng::new(3);
        let joined: Vec<JoinedRecord> = (0..10_000)
            .map(|_| JoinedRecord {
                site: if rng.chance(0.05) {
                    -1
                } else {
                    rng.gen_range(k.meta.num_sites as u64) as i32
                },
                week: rng.gen_range(k.meta.num_weeks as u64) as i32,
                marked: f32::from(rng.chance(0.3)),
            })
            .collect();
        let got = k.hist(&joined).unwrap();
        let mut want = MalstoneResult::zero(k.meta.num_sites, k.meta.num_weeks);
        want.accumulate(&joined);
        assert_eq!(got, want);
    }

    #[test]
    fn ratio_graphs_match_oracle() {
        let Some(k) = kernels() else { return };
        let g = MalGen::new(MalGenConfig::small(17));
        let all = g.generate_all(2, 3_000);
        let table = compromise_table(&all);
        let joined = bucketize(
            &all,
            &table,
            k.meta.num_sites as u32,
            k.meta.num_weeks as u32,
            SECONDS_PER_WEEK,
        );
        let planes = k.hist(&joined).unwrap();
        let ra = k.ratio_a(&planes).unwrap();
        let rb = k.ratio_b(&planes).unwrap();
        let want_a = planes.ratio_a();
        let want_b = planes.ratio_b();
        assert_eq!(ra.len(), k.meta.num_sites);
        assert_eq!(rb.len(), k.meta.num_sites * k.meta.num_weeks);
        for (g, w) in ra.iter().zip(&want_a) {
            assert!((*g as f64 - w).abs() < 1e-6, "{g} vs {w}");
        }
        for (g, w) in rb.iter().zip(&want_b) {
            assert!((*g as f64 - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn sphere_execute_with_kernel_aggregator() {
        let Some(k) = kernels() else { return };
        let g = MalGen::new(MalGenConfig::small(23));
        let shards: Vec<Vec<crate::malstone::Record>> =
            (0..3).map(|s| g.generate_shard(s, 3, 1_000)).collect();
        let with_kernel = crate::sector::sphere::execute_malstone_with(
            &shards, 4, k.meta.num_sites as u32, k.meta.num_weeks as u32,
            SECONDS_PER_WEEK, k.aggregator(),
        );
        let with_cpu = crate::sector::sphere::execute_malstone_with(
            &shards, 4, k.meta.num_sites as u32, k.meta.num_weeks as u32,
            SECONDS_PER_WEEK, crate::sector::sphere::cpu_aggregator,
        );
        assert_eq!(with_kernel, with_cpu);
        assert!(*k.hist_calls.borrow() >= 4);
    }
}

//! Shared utilities: deterministic RNG, statistics, unit formatting, and a
//! dependency-free JSON reader/writer (the build environment is offline, so
//! rand/serde are implemented in-tree at the scale this crate needs).

pub mod json;
pub mod rng;
pub mod stats;
pub mod units;

pub use rng::Rng;

//! Small statistics helpers used by the monitor, benches, and detectors.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (of a copy; input untouched).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, `p` in [0, 100]. 0.0 on empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Linear-interpolated percentile over an **already-sorted** slice — the
/// allocation-free core of [`percentile`], for callers that keep their
/// own sorted scratch buffer. 0.0 on empty input.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Online mean/min/max/count accumulator (used by bench harness + monitor).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn empty_inputs_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_accumulates() {
        let mut s = Summary::new();
        for x in [3.0, 1.0, 2.0] {
            s.add(x);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean(), 2.0);
    }
}

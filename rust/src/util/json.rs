//! Minimal JSON reader/writer. The offline build has no serde; this covers
//! the two uses in the crate: parsing `artifacts/meta.json` and exporting
//! monitoring frames / experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (sufficient for our metadata).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization; `to_string()` round-trips through [`Json::parse`].
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut xs = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    xs.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => return Err(format!("bad array at offset {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    m.insert(k, self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at offset {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at offset {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at offset {}", start))
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_meta_json_shape() {
        let src = r#"{"num_sites": 256, "artifacts": ["a", "b"], "ok": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("num_sites").unwrap().as_u64(), Some(256));
        assert_eq!(
            v.get("artifacts").unwrap(),
            &Json::Arr(vec![Json::Str("a".into()), Json::Str("b".into())])
        );
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_nested_and_negative() {
        let v = Json::parse(r#"{"a": {"b": [-1.5, 2e3, null]}}"#).unwrap();
        let arr = v.get("a").unwrap().get("b").unwrap();
        assert_eq!(arr, &Json::Arr(vec![Json::Num(-1.5), Json::Num(2000.0), Json::Null]));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("line\n\"quote\"\tx".into());
        let re = Json::parse(&original.to_string()).unwrap();
        assert_eq!(re, original);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v, Json::Str("A".into()));
    }
}

//! Deterministic pseudo-random number generation (xoshiro256** seeded via
//! splitmix64). Every stochastic component in the testbed — MalGen, the
//! simulator, fault injection — draws from an explicitly seeded [`Rng`], so
//! whole experiments replay bit-identically from a seed.

/// xoshiro256** generator. Not cryptographic; fast, high-quality, and
/// deterministic across platforms, which is all the simulator needs.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. one per simulated node).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift rejection method: unbiased.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly. Panics on empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Zipf sampler over `{0, .., n-1}` with exponent `s` (site popularity in
/// MalGen follows a power law: a few sites receive most visits).
/// Precomputes the CDF; sampling is a binary search.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.gen_range(17);
            assert!(x < 17);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(17);
        let z = Zipf::new(100, 1.2);
        let mut counts = [0u32; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Head should dominate the tail under s=1.2.
        assert!(counts[0] > counts[50] * 5);
        assert!(counts[0] > counts[99]);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.1);
        assert!((var.sqrt() - 2.0).abs() < 0.1);
    }
}

//! Unit constants and formatting, including the paper's `454m 13s` time
//! format used in Table 1.

/// Bits per second in one gigabit per second.
pub const GBPS: f64 = 1e9;
/// Bits per second in one megabit per second.
pub const MBPS: f64 = 1e6;
/// Bytes in a kibibyte/mebibyte/gibibyte.
pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * 1024;
pub const GIB: u64 = 1024 * 1024 * 1024;
/// Bytes in the decimal units MalStone uses (1 TB = 10^12 bytes).
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;

/// Format seconds in the paper's Table-1 style: `"454m 13s"`.
pub fn fmt_paper_time(secs: f64) -> String {
    let total = secs.round().max(0.0) as u64;
    format!("{}m {:02}s", total / 60, total % 60)
}

/// Format seconds adaptively for logs (`1.23 ms`, `45.6 s`, `12m 05s`).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else {
        fmt_paper_time(secs)
    }
}

/// Format a byte count (decimal units, matching the paper's "1 TB").
pub fn fmt_bytes(bytes: u64) -> String {
    if bytes >= TB {
        format!("{:.2} TB", bytes as f64 / TB as f64)
    } else if bytes >= GB {
        format!("{:.2} GB", bytes as f64 / GB as f64)
    } else if bytes >= MB {
        format!("{:.2} MB", bytes as f64 / MB as f64)
    } else {
        format!("{} B", bytes)
    }
}

/// Format a bit rate.
pub fn fmt_rate(bps: f64) -> String {
    if bps >= GBPS {
        format!("{:.2} Gb/s", bps / GBPS)
    } else if bps >= MBPS {
        format!("{:.1} Mb/s", bps / MBPS)
    } else {
        format!("{:.0} b/s", bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_time_matches_table1_style() {
        assert_eq!(fmt_paper_time(454.0 * 60.0 + 13.0), "454m 13s");
        assert_eq!(fmt_paper_time(33.0 * 60.0 + 40.0), "33m 40s");
        assert_eq!(fmt_paper_time(0.0), "0m 00s");
        assert_eq!(fmt_paper_time(59.6), "1m 00s");
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(TB), "1.00 TB");
        assert_eq!(fmt_bytes(1_500_000_000), "1.50 GB");
        assert_eq!(fmt_bytes(100), "100 B");
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(10.0 * GBPS), "10.00 Gb/s");
        assert_eq!(fmt_rate(940.0 * MBPS), "940.0 Mb/s");
    }

    #[test]
    fn adaptive_time() {
        assert_eq!(fmt_time(0.0000005), "0.5 µs");
        assert_eq!(fmt_time(0.5), "500.00 ms");
        assert_eq!(fmt_time(7200.0), "120m 00s");
    }
}

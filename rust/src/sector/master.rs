//! Sector master: SDFS metadata, topology-aware placement, blacklist.
//!
//! Sector 1.20 semantics: files are stored as whole segments on slave
//! nodes (no striping); writes land on the client's slave (or the
//! topologically closest slave with capacity); replication happens lazily
//! in the background, so benchmarks see single-copy write cost. The
//! master also tracks the slave blacklist driven by the monitoring system
//! (paper §3: "Sector can remove underperforming resources").

use std::collections::{BTreeMap, BTreeSet};
use std::rc::Rc;

use crate::net::{NodeId, Topology};

/// One stored segment (Sector files are segment-granular).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub node: NodeId,
    pub bytes: u64,
    pub records: u64,
}

/// The Sector master.
pub struct SectorMaster {
    topo: Rc<Topology>,
    files: BTreeMap<String, Vec<Segment>>,
    blacklist: BTreeSet<NodeId>,
    /// Bytes stored per slave.
    usage: BTreeMap<NodeId, u64>,
}

impl SectorMaster {
    pub fn new(topo: Rc<Topology>) -> Self {
        SectorMaster {
            topo,
            files: BTreeMap::new(),
            blacklist: BTreeSet::new(),
            usage: BTreeMap::new(),
        }
    }

    /// Register a file whose segments already live on their home slaves
    /// (MalGen writes shards locally — Sector's normal ingest pattern).
    pub fn register_file(&mut self, name: &str, segments: Vec<Segment>) {
        assert!(!self.files.contains_key(name), "file exists: {name}");
        for s in &segments {
            *self.usage.entry(s.node).or_insert(0) += s.bytes;
        }
        self.files.insert(name.to_string(), segments);
    }

    pub fn file_segments(&self, name: &str) -> Option<&[Segment]> {
        self.files.get(name).map(|v| v.as_slice())
    }

    /// Choose a write target near `client`: the client's own slave if
    /// healthy, else the closest healthy slave with least usage.
    pub fn choose_write_target(&self, client: NodeId) -> NodeId {
        if !self.blacklist.contains(&client) {
            return client;
        }
        self.topo
            .node_ids()
            .into_iter()
            .filter(|n| !self.blacklist.contains(n))
            .min_by_key(|&n| {
                (self.topo.distance(client, n), self.usage.get(&n).copied().unwrap_or(0))
            })
            .expect("all slaves blacklisted")
    }

    /// Blacklist a slave (monitor feedback). Existing data stays readable;
    /// the scheduler stops assigning work there.
    pub fn blacklist(&mut self, n: NodeId) {
        self.blacklist.insert(n);
    }

    pub fn unblacklist(&mut self, n: NodeId) {
        self.blacklist.remove(&n);
    }

    pub fn is_blacklisted(&self, n: NodeId) -> bool {
        self.blacklist.contains(&n)
    }

    /// Healthy subset of a node list.
    pub fn healthy<'a>(&self, nodes: &'a [NodeId]) -> Vec<NodeId> {
        nodes.iter().copied().filter(|n| !self.blacklist.contains(n)).collect()
    }

    pub fn usage(&self, n: NodeId) -> u64 {
        self.usage.get(&n).copied().unwrap_or(0)
    }

    pub fn topology(&self) -> &Rc<Topology> {
        &self.topo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Topology;

    fn master() -> SectorMaster {
        SectorMaster::new(Rc::new(Topology::oct_2009()))
    }

    #[test]
    fn register_and_lookup() {
        let mut m = master();
        let segs = vec![
            Segment { node: NodeId(0), bytes: 100, records: 1 },
            Segment { node: NodeId(1), bytes: 200, records: 2 },
        ];
        m.register_file("data", segs.clone());
        assert_eq!(m.file_segments("data").unwrap(), segs.as_slice());
        assert_eq!(m.usage(NodeId(1)), 200);
        assert!(m.file_segments("nope").is_none());
    }

    #[test]
    fn write_target_is_local_when_healthy() {
        let m = master();
        assert_eq!(m.choose_write_target(NodeId(5)), NodeId(5));
    }

    #[test]
    fn blacklisted_client_redirects_nearby() {
        let mut m = master();
        m.blacklist(NodeId(5));
        let t = m.choose_write_target(NodeId(5));
        assert_ne!(t, NodeId(5));
        // Redirect should stay in the same rack (distance 1).
        assert_eq!(m.topology().distance(NodeId(5), t), 1);
    }

    #[test]
    fn healthy_filters_blacklist() {
        let mut m = master();
        m.blacklist(NodeId(1));
        let h = m.healthy(&[NodeId(0), NodeId(1), NodeId(2)]);
        assert_eq!(h, vec![NodeId(0), NodeId(2)]);
        m.unblacklist(NodeId(1));
        assert!(!m.is_blacklisted(NodeId(1)));
    }
}

//! Sphere: the UDF engine (simulate + execute faces, like
//! `hadoop::mapreduce`).
//!
//! Stage 1 ("scan"): every Sphere Processing Engine streams its node's
//! local segments through the UDF — disk read, per-record CPU — and
//! hash-partitions output into bucket files pushed over **UDT** to every
//! node as they are produced. Idle SPEs *steal* pending segments from
//! busy or blacklisted nodes (reading remotely over UDT): the paper's
//! "bandwidth load balancing". Stage 2 ("aggregate"): each node folds the
//! buckets it received — in the real path this is the AOT-compiled
//! JAX/Pallas histogram kernel — and the master merges the tiny planes.
//!
//! [`SphereEngine::simulate`] is a thin instantiation of the shared
//! [`crate::framework`] runtime: Sector storage (writer-local, lazy
//! replication), stealing-enabled slot scheduling, and the overlapped
//! [`crate::framework::ExchangeModel::BucketPush`] exchange over UDT.
//! The differences that produce Table 2's 4.7% Sector penalty vs Hadoop's
//! 31–34% are all mechanistic in those layer choices: UDT rate caps
//! (RTT-insensitive) instead of TCP's window/Mathis ceilings, single lazy
//! replication instead of a 3-way synchronous pipeline, and segment
//! stealing that soaks up stragglers.

use std::cell::RefCell;
use std::rc::Rc;

use crate::framework::{
    DataflowControl, DataflowEngine, DataflowSpec, ExchangeModel, SectorStorage, StealPolicy,
    TaskInput,
};
use crate::hadoop::params::FrameworkParams;
use crate::malstone::join::{bucketize, compromise_table, JoinedRecord};
use crate::malstone::oracle::MalstoneResult;
use crate::malstone::record::Record;
use crate::net::{Cluster, NodeId};
use crate::sim::Engine;

use super::master::{SectorMaster, Segment};

/// Timing report for one simulated Sphere run.
#[derive(Debug, Clone)]
pub struct SphereReport {
    pub name: String,
    pub makespan: f64,
    pub scan_phase: f64,
    pub aggregate_phase: f64,
    pub segments: usize,
    pub stolen_segments: usize,
    /// Segments re-executed on survivors after a slave was declared lost
    /// mid-run (see [`DataflowControl::heal_node`]).
    pub reexecuted_segments: usize,
    /// Intermediate bytes that crossed the network during the push (the
    /// paper's accounting; node-local shares excluded).
    pub exchange_bytes: f64,
    /// All bytes through the exchange, node-local bucket shares included
    /// (comparable to Hadoop's `shuffle_bytes`).
    pub exchange_total_bytes: f64,
    /// Segment bytes read through the storage layer.
    pub storage_read_bytes: f64,
    /// Output bytes written through the storage layer (zero: stage 2
    /// keeps its histogram planes in memory; the master gather is
    /// negligible).
    pub storage_write_bytes: f64,
}

/// The Sphere timing engine: Sector/Sphere semantics instantiated on the
/// shared [`crate::framework`] dataflow runtime.
pub struct SphereEngine;

impl SphereEngine {
    /// Simulate a MalStone-style two-stage UDF over `file` on `master`'s
    /// healthy subset of `nodes`.
    #[allow(clippy::too_many_arguments)]
    pub fn simulate<F: FnOnce(&mut Engine, SphereReport) + 'static>(
        cluster: &Cluster,
        master: &SectorMaster,
        eng: &mut Engine,
        file: &str,
        nodes: &[NodeId],
        params: FrameworkParams,
        variant_b: bool,
        done: F,
    ) -> DataflowControl {
        let healthy = master.healthy(nodes);
        assert!(!healthy.is_empty(), "no healthy slaves");
        let segments: Vec<Segment> = master
            .file_segments(file)
            .unwrap_or_else(|| panic!("unknown sector file {file}"))
            .to_vec();
        assert!(!segments.is_empty());
        let spe_slots = 2; // SPE threads per slave doing segment work
        let dataflow = DataflowSpec {
            name: format!("sphere-malstone-{}", if variant_b { "b" } else { "a" }),
            num_reducers: healthy.len(),
            nodes: healthy,
            tasks: segments
                .iter()
                .map(|s| TaskInput { node: s.node, bytes: s.bytes, records: s.records })
                .collect(),
            slots_per_node: spe_slots,
            task_overhead: params.task_overhead,
            map_cpu_per_record: params.map_cpu_per_record,
            reduce_cpu_per_record: params.reduce_cpu(variant_b),
            intermediate_bytes_per_record: params.intermediate_bytes_per_record(variant_b),
            // Stage 2 aggregates in memory; output planes are negligible
            // and the master gather is charged as zero bytes.
            output_bytes_per_record: 0.0,
            merge_passes: 0.0,
            protocol: params.protocol.clone(),
            exchange: ExchangeModel::BucketPush,
            steal: StealPolicy::Anywhere,
        };
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        DataflowEngine::run(cluster, storage, eng, dataflow, move |eng, r| {
            let report = SphereReport {
                name: r.name,
                makespan: r.makespan,
                scan_phase: r.phase1,
                aggregate_phase: r.phase2,
                segments: r.tasks,
                stolen_segments: r.remote_tasks,
                reexecuted_segments: r.reexecuted,
                exchange_bytes: r.exchange_remote_bytes,
                exchange_total_bytes: r.exchange_bytes,
                storage_read_bytes: r.storage_read_bytes,
                storage_write_bytes: r.storage_write_bytes,
            };
            done(eng, report);
        })
    }
}

/// Execute MalStone for real with Sphere dataflow semantics: stage-1 UDF
/// hash-partitions records into buckets by entity; stage 2 folds each
/// bucket through `aggregator` (the pure-Rust fold, or the AOT PJRT
/// kernel from `runtime::MalstoneKernels::aggregator`) and merges.
pub fn execute_malstone_with<A>(
    shards: &[Vec<Record>],
    num_buckets: usize,
    num_sites: u32,
    num_weeks: u32,
    seconds_per_week: u64,
    mut aggregator: A,
) -> MalstoneResult
where
    A: FnMut(&[JoinedRecord], u32, u32) -> MalstoneResult,
{
    assert!(num_buckets > 0);
    let mut buckets: Vec<Vec<Record>> = vec![Vec::new(); num_buckets];
    for shard in shards {
        for r in shard {
            let h = r.entity_id.wrapping_mul(0x9E3779B97F4A7C15) >> 32;
            buckets[(h % num_buckets as u64) as usize].push(*r);
        }
    }
    let mut global = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
    for bucket in &buckets {
        let table = compromise_table(bucket);
        let joined = bucketize(bucket, &table, num_sites, num_weeks, seconds_per_week);
        let partial = aggregator(&joined, num_sites, num_weeks);
        global.merge(&partial);
    }
    global
}

/// The pure-Rust stage-2 aggregator (baseline; the PJRT kernel is the
/// optimized drop-in).
pub fn cpu_aggregator(joined: &[JoinedRecord], num_sites: u32, num_weeks: u32) -> MalstoneResult {
    let mut r = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
    r.accumulate(joined);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
    use crate::malstone::record::RECORD_BYTES;
    use crate::net::Topology;

    fn setup(nodes_per_site: usize, records: u64) -> (Cluster, SectorMaster, Vec<NodeId>) {
        let cluster = Cluster::new(Topology::oct_2009());
        let mut master = SectorMaster::new(cluster.topo.clone());
        let mut nodes = Vec::new();
        for r in 0..4 {
            for i in 0..nodes_per_site {
                nodes.push(cluster.topo.racks[r].nodes[i]);
            }
        }
        let per = records / nodes.len() as u64;
        // Real SDFS stores 64 MB segments — that granularity is what gives
        // the load balancer something to steal.
        let seg_bytes: u64 = 64 * 1024 * 1024;
        let seg_records = seg_bytes / RECORD_BYTES as u64;
        let mut segs = Vec::new();
        for &n in &nodes {
            let mut left = per;
            while left > 0 {
                let r = left.min(seg_records);
                segs.push(Segment { node: n, bytes: r * RECORD_BYTES as u64, records: r });
                left -= r;
            }
        }
        master.register_file("malstone", segs);
        (cluster, master, nodes)
    }

    fn run(
        cluster: &Cluster,
        master: &SectorMaster,
        nodes: &[NodeId],
        variant_b: bool,
    ) -> SphereReport {
        let mut eng = Engine::new();
        let out = Rc::new(RefCell::new(None));
        let o = out.clone();
        SphereEngine::simulate(
            cluster,
            master,
            &mut eng,
            "malstone",
            nodes,
            FrameworkParams::sphere(),
            variant_b,
            move |_, r| *o.borrow_mut() = Some(r),
        );
        eng.run();
        let r = out.borrow_mut().take().expect("sphere did not finish");
        r
    }

    #[test]
    fn completes_with_phases() {
        let (cluster, master, nodes) = setup(2, 8_000_000);
        let r = run(&cluster, &master, &nodes, false);
        assert!(r.makespan > 0.0);
        assert!(r.scan_phase > 0.0 && r.aggregate_phase > 0.0);
        assert_eq!(r.segments, 16); // 1M records/node = 2 segments × 8 nodes
        assert!(r.exchange_bytes > 0.0);
    }

    #[test]
    fn variant_b_costs_more() {
        let (cluster, master, nodes) = setup(2, 8_000_000);
        let a = run(&cluster, &master, &nodes, false);
        let b = run(&cluster, &master, &nodes, true);
        assert!(b.makespan > a.makespan);
    }

    #[test]
    fn blacklisted_node_gets_no_work_but_job_finishes() {
        let (cluster, mut master, nodes) = setup(2, 8_000_000);
        master.blacklist(nodes[0]);
        let r = run(&cluster, &master, &nodes, false);
        // Its segment was stolen by another node.
        assert!(r.stolen_segments >= 1);
        assert_eq!(r.segments, 16);
    }

    #[test]
    fn stealing_soaks_up_cpu_straggler() {
        let (cluster, master, nodes) = setup(2, 40_000_000);
        let healthy = run(&cluster, &master, &nodes, false);
        // Degrade one node's CPU 4×; stealing should keep the slowdown
        // well below proportional.
        let (cluster2, master2, nodes2) = setup(2, 40_000_000);
        cluster2.set_node_speed(nodes2[0], 0.25);
        let degraded = run(&cluster2, &master2, &nodes2, false);
        assert!(degraded.makespan < healthy.makespan * 2.0,
            "straggler not absorbed: {} vs {}", degraded.makespan, healthy.makespan);
    }

    #[test]
    fn execute_matches_mapreduce_and_oracle() {
        let g = MalGen::new(MalGenConfig::small(29));
        let shards: Vec<Vec<Record>> = (0..4).map(|s| g.generate_shard(s, 4, 1_500)).collect();
        let sphere = execute_malstone_with(&shards, 6, 256, 64, SECONDS_PER_WEEK, cpu_aggregator);
        let mr = crate::hadoop::mapreduce::execute_malstone(&shards, 6, 256, 64, SECONDS_PER_WEEK);
        assert_eq!(sphere, mr);
        // And against the single-machine oracle.
        let all: Vec<Record> = shards.iter().flatten().copied().collect();
        let table = compromise_table(&all);
        let joined = bucketize(&all, &table, 256, 64, SECONDS_PER_WEEK);
        let mut oracle = MalstoneResult::zero(256, 64);
        oracle.accumulate(&joined);
        assert_eq!(sphere, oracle);
    }

    #[test]
    fn bucket_count_invariance_property() {
        crate::proptest::check("sphere bucket-count invariance", 10, |rng| {
            let g = MalGen::new(MalGenConfig::small(rng.next_u64()));
            let shards: Vec<Vec<Record>> = (0..3).map(|s| g.generate_shard(s, 3, 400)).collect();
            let a = execute_malstone_with(&shards, 1, 64, 16, SECONDS_PER_WEEK * 4, cpu_aggregator);
            let k = 2 + rng.gen_range(7) as usize;
            let b = execute_malstone_with(&shards, k, 64, 16, SECONDS_PER_WEEK * 4, cpu_aggregator);
            if a == b {
                Ok(())
            } else {
                Err(format!("bucket count {k} changed result"))
            }
        });
    }
}

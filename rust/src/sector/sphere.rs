//! Sphere: the UDF engine (simulate + execute faces, like
//! `hadoop::mapreduce`).
//!
//! Stage 1 ("scan"): every Sphere Processing Engine streams its node's
//! local segments through the UDF — disk read, per-record CPU — and
//! hash-partitions output into bucket files pushed over **UDT** to every
//! node as they are produced. Idle SPEs *steal* pending segments from
//! busy or blacklisted nodes (reading remotely over UDT): the paper's
//! "bandwidth load balancing". Stage 2 ("aggregate"): each node folds the
//! buckets it received — in the real path this is the AOT-compiled
//! JAX/Pallas histogram kernel — and the master merges the tiny planes.
//!
//! The differences that produce Table 2's 4.7% Sector penalty vs Hadoop's
//! 31–34% are all mechanistic here: UDT rate caps (RTT-insensitive)
//! instead of TCP's window/Mathis ceilings, single lazy replication
//! instead of a 3-way synchronous pipeline, and segment stealing that
//! soaks up stragglers.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::hadoop::params::FrameworkParams;
use crate::malstone::join::{bucketize, compromise_table, JoinedRecord};
use crate::malstone::oracle::MalstoneResult;
use crate::malstone::record::Record;
use crate::net::{Cluster, NodeId};
use crate::sim::resources::CpuPool;
use crate::sim::Engine;
use crate::transport;

use super::master::{SectorMaster, Segment};

/// Timing report for one simulated Sphere run.
#[derive(Debug, Clone)]
pub struct SphereReport {
    pub name: String,
    pub makespan: f64,
    pub scan_phase: f64,
    pub aggregate_phase: f64,
    pub segments: usize,
    pub stolen_segments: usize,
    pub exchange_bytes: f64,
}

struct SphereState {
    cluster: Cluster,
    params: FrameworkParams,
    variant_b: bool,
    nodes: Vec<NodeId>,
    pending: Vec<Segment>,
    running: usize,
    slots_free: HashMap<NodeId, usize>,
    /// Intermediate bytes/records routed to each node's buckets.
    bucket_bytes: HashMap<NodeId, f64>,
    bucket_records: HashMap<NodeId, f64>,
    stolen: usize,
    segments_total: usize,
    segments_done: usize,
    exchange_bytes: f64,
    scan_end: f64,
    start: f64,
    agg_done: usize,
    done_cb: Option<Box<dyn FnOnce(&mut Engine, SphereReport)>>,
}

/// The Sphere timing engine.
pub struct SphereEngine;

impl SphereEngine {
    /// Simulate a MalStone-style two-stage UDF over `file` on `master`'s
    /// healthy subset of `nodes`.
    #[allow(clippy::too_many_arguments)]
    pub fn simulate<F: FnOnce(&mut Engine, SphereReport) + 'static>(
        cluster: &Cluster,
        master: &SectorMaster,
        eng: &mut Engine,
        file: &str,
        nodes: &[NodeId],
        params: FrameworkParams,
        variant_b: bool,
        done: F,
    ) {
        let healthy = master.healthy(nodes);
        assert!(!healthy.is_empty(), "no healthy slaves");
        let segments: Vec<Segment> = master
            .file_segments(file)
            .unwrap_or_else(|| panic!("unknown sector file {file}"))
            .to_vec();
        assert!(!segments.is_empty());
        let spe_slots = 2; // SPE threads per slave doing segment work
        let st = Rc::new(RefCell::new(SphereState {
            cluster: cluster.clone(),
            params,
            variant_b,
            slots_free: healthy.iter().map(|&n| (n, spe_slots)).collect(),
            nodes: healthy,
            segments_total: segments.len(),
            pending: segments,
            running: 0,
            bucket_bytes: HashMap::new(),
            bucket_records: HashMap::new(),
            stolen: 0,
            segments_done: 0,
            exchange_bytes: 0.0,
            scan_end: 0.0,
            start: eng.now(),
            agg_done: 0,
            done_cb: Some(Box::new(done)),
        }));
        Self::fill_slots(&st, eng);
    }

    /// Locality-first, stealing-allowed segment scheduling.
    fn fill_slots(st: &Rc<RefCell<SphereState>>, eng: &mut Engine) {
        loop {
            let task = {
                let mut s = st.borrow_mut();
                if s.pending.is_empty() {
                    None
                } else {
                    let topo = s.cluster.topo.clone();
                    let nodes = s.nodes.clone();
                    let mut found = None;
                    'outer: for &n in &nodes {
                        if s.slots_free[&n] == 0 {
                            continue;
                        }
                        let mut best: Option<(usize, u32)> = None;
                        for (i, seg) in s.pending.iter().enumerate() {
                            let d = topo.distance(n, seg.node);
                            if best.map_or(true, |(_, bd)| d < bd) {
                                best = Some((i, d));
                            }
                            if d == 0 {
                                break;
                            }
                        }
                        if let Some((i, d)) = best {
                            let seg = s.pending.swap_remove(i);
                            *s.slots_free.get_mut(&n).unwrap() -= 1;
                            s.running += 1;
                            if d > 0 {
                                s.stolen += 1;
                            }
                            found = Some((n, seg));
                            break 'outer;
                        }
                    }
                    found
                }
            };
            match task {
                Some((node, seg)) => Self::run_segment(st, eng, node, seg),
                None => break,
            }
        }
    }

    /// One segment through stage 1: (possibly remote) read → UDF CPU →
    /// bucket exchange over UDT, overlapped (flows start as CPU ends; the
    /// segment completes when its slowest bucket push lands).
    fn run_segment(st: &Rc<RefCell<SphereState>>, eng: &mut Engine, node: NodeId, seg: Segment) {
        let (cluster, proto, overhead) = {
            let s = st.borrow();
            (s.cluster.clone(), s.params.protocol.clone(), s.params.task_overhead)
        };
        let st2 = st.clone();
        let net = cluster.net.clone();
        let topo = cluster.topo.clone();
        eng.schedule_in(overhead, move |eng| {
            let st3 = st2.clone();
            let after_read = move |eng: &mut Engine| {
                let (pool, cpu) = {
                    let s = st3.borrow();
                    (s.cluster.pool(node).clone(), seg.records as f64 * s.params.map_cpu_per_record)
                };
                let st4 = st3.clone();
                CpuPool::submit(&pool, eng, cpu, move |eng| {
                    Self::exchange(&st4, eng, node, seg);
                });
            };
            if seg.node == node {
                transport::disk_read(&net, &topo, eng, node, seg.bytes as f64, after_read);
            } else {
                // Stolen segment: stream it from its home slave over UDT.
                let net2 = net.clone();
                let topo2 = topo.clone();
                transport::disk_read(&net, &topo, eng, seg.node, seg.bytes as f64, move |eng| {
                    transport::send(&net2, &topo2, eng, seg.node, node, seg.bytes as f64, &proto, after_read);
                });
            }
        });
    }

    /// Push this segment's UDF output into bucket files on every node.
    fn exchange(st: &Rc<RefCell<SphereState>>, eng: &mut Engine, node: NodeId, seg: Segment) {
        let (cluster, proto, out_bytes, nodes) = {
            let s = st.borrow();
            let out = seg.records as f64 * s.params.intermediate_bytes_per_record(s.variant_b);
            (s.cluster.clone(), s.params.protocol.clone(), out, s.nodes.clone())
        };
        let n = nodes.len() as f64;
        let share_bytes = out_bytes / n;
        let share_records = seg.records as f64 / n;
        let legs = Rc::new(RefCell::new(nodes.len()));
        let st2 = st.clone();
        let arrive = move |st: &Rc<RefCell<SphereState>>, eng: &mut Engine, legs: &Rc<RefCell<usize>>| {
            let mut l = legs.borrow_mut();
            *l -= 1;
            if *l == 0 {
                Self::segment_finished(st, eng, node);
            }
        };
        for &dst in &nodes {
            {
                let mut s = st.borrow_mut();
                *s.bucket_bytes.entry(dst).or_insert(0.0) += share_bytes;
                *s.bucket_records.entry(dst).or_insert(0.0) += share_records;
                if dst != node {
                    s.exchange_bytes += share_bytes;
                }
            }
            let st3 = st2.clone();
            let legs2 = legs.clone();
            let done = move |eng: &mut Engine| arrive(&st3, eng, &legs2);
            if dst == node {
                transport::disk_write(&cluster.net, &cluster.topo, eng, node, share_bytes, done);
            } else {
                let net = cluster.net.clone();
                let topo = cluster.topo.clone();
                transport::send(&cluster.net, &cluster.topo, eng, node, dst, share_bytes, &proto, move |eng| {
                    transport::disk_write(&net, &topo, eng, dst, share_bytes, done);
                });
            }
        }
    }

    fn segment_finished(st: &Rc<RefCell<SphereState>>, eng: &mut Engine, node: NodeId) {
        let scan_done = {
            let mut s = st.borrow_mut();
            s.segments_done += 1;
            s.running -= 1;
            *s.slots_free.get_mut(&node).unwrap() += 1;
            if s.segments_done == s.segments_total {
                s.scan_end = eng.now();
                true
            } else {
                false
            }
        };
        Self::fill_slots(st, eng);
        if scan_done {
            Self::start_aggregate(st, eng);
        }
    }

    /// Stage 2: every node folds its buckets; the merged planes are tiny
    /// (the master gather is negligible and charged as zero bytes).
    fn start_aggregate(st: &Rc<RefCell<SphereState>>, eng: &mut Engine) {
        let nodes = st.borrow().nodes.clone();
        for node in nodes {
            let (cluster, bytes, records, cpu_per_rec) = {
                let s = st.borrow();
                (
                    s.cluster.clone(),
                    s.bucket_bytes.get(&node).copied().unwrap_or(0.0),
                    s.bucket_records.get(&node).copied().unwrap_or(0.0),
                    s.params.reduce_cpu(s.variant_b),
                )
            };
            let st2 = st.clone();
            let pool = cluster.pool(node).clone();
            transport::disk_read(&cluster.net, &cluster.topo, eng, node, bytes, move |eng| {
                let st3 = st2.clone();
                CpuPool::submit(&pool, eng, records * cpu_per_rec, move |eng| {
                    let mut s = st3.borrow_mut();
                    s.agg_done += 1;
                    if s.agg_done == s.nodes.len() {
                        let report = SphereReport {
                            name: format!(
                                "sphere-malstone-{}",
                                if s.variant_b { "b" } else { "a" }
                            ),
                            makespan: eng.now() - s.start,
                            scan_phase: s.scan_end - s.start,
                            aggregate_phase: eng.now() - s.scan_end,
                            segments: s.segments_total,
                            stolen_segments: s.stolen,
                            exchange_bytes: s.exchange_bytes,
                        };
                        let cb = s.done_cb.take().unwrap();
                        drop(s);
                        cb(eng, report);
                    }
                });
            });
        }
    }
}

/// Execute MalStone for real with Sphere dataflow semantics: stage-1 UDF
/// hash-partitions records into buckets by entity; stage 2 folds each
/// bucket through `aggregator` (the pure-Rust fold, or the AOT PJRT
/// kernel from `runtime::MalstoneKernels::aggregator`) and merges.
pub fn execute_malstone_with<A>(
    shards: &[Vec<Record>],
    num_buckets: usize,
    num_sites: u32,
    num_weeks: u32,
    seconds_per_week: u64,
    mut aggregator: A,
) -> MalstoneResult
where
    A: FnMut(&[JoinedRecord], u32, u32) -> MalstoneResult,
{
    assert!(num_buckets > 0);
    let mut buckets: Vec<Vec<Record>> = vec![Vec::new(); num_buckets];
    for shard in shards {
        for r in shard {
            let h = r.entity_id.wrapping_mul(0x9E3779B97F4A7C15) >> 32;
            buckets[(h % num_buckets as u64) as usize].push(*r);
        }
    }
    let mut global = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
    for bucket in &buckets {
        let table = compromise_table(bucket);
        let joined = bucketize(bucket, &table, num_sites, num_weeks, seconds_per_week);
        let partial = aggregator(&joined, num_sites, num_weeks);
        global.merge(&partial);
    }
    global
}

/// The pure-Rust stage-2 aggregator (baseline; the PJRT kernel is the
/// optimized drop-in).
pub fn cpu_aggregator(joined: &[JoinedRecord], num_sites: u32, num_weeks: u32) -> MalstoneResult {
    let mut r = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
    r.accumulate(joined);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
    use crate::malstone::record::RECORD_BYTES;
    use crate::net::Topology;

    fn setup(nodes_per_site: usize, records: u64) -> (Cluster, SectorMaster, Vec<NodeId>) {
        let cluster = Cluster::new(Topology::oct_2009());
        let mut master = SectorMaster::new(cluster.topo.clone());
        let mut nodes = Vec::new();
        for r in 0..4 {
            for i in 0..nodes_per_site {
                nodes.push(cluster.topo.racks[r].nodes[i]);
            }
        }
        let per = records / nodes.len() as u64;
        // Real SDFS stores 64 MB segments — that granularity is what gives
        // the load balancer something to steal.
        let seg_bytes: u64 = 64 * 1024 * 1024;
        let seg_records = seg_bytes / RECORD_BYTES as u64;
        let mut segs = Vec::new();
        for &n in &nodes {
            let mut left = per;
            while left > 0 {
                let r = left.min(seg_records);
                segs.push(Segment { node: n, bytes: r * RECORD_BYTES as u64, records: r });
                left -= r;
            }
        }
        master.register_file("malstone", segs);
        (cluster, master, nodes)
    }

    fn run(cluster: &Cluster, master: &SectorMaster, nodes: &[NodeId], variant_b: bool) -> SphereReport {
        let mut eng = Engine::new();
        let out = Rc::new(RefCell::new(None));
        let o = out.clone();
        SphereEngine::simulate(
            cluster,
            master,
            &mut eng,
            "malstone",
            nodes,
            FrameworkParams::sphere(),
            variant_b,
            move |_, r| *o.borrow_mut() = Some(r),
        );
        eng.run();
        let r = out.borrow_mut().take().expect("sphere did not finish");
        r
    }

    #[test]
    fn completes_with_phases() {
        let (cluster, master, nodes) = setup(2, 8_000_000);
        let r = run(&cluster, &master, &nodes, false);
        assert!(r.makespan > 0.0);
        assert!(r.scan_phase > 0.0 && r.aggregate_phase > 0.0);
        assert_eq!(r.segments, 16); // 1M records/node = 2 segments × 8 nodes
        assert!(r.exchange_bytes > 0.0);
    }

    #[test]
    fn variant_b_costs_more() {
        let (cluster, master, nodes) = setup(2, 8_000_000);
        let a = run(&cluster, &master, &nodes, false);
        let b = run(&cluster, &master, &nodes, true);
        assert!(b.makespan > a.makespan);
    }

    #[test]
    fn blacklisted_node_gets_no_work_but_job_finishes() {
        let (cluster, mut master, nodes) = setup(2, 8_000_000);
        master.blacklist(nodes[0]);
        let r = run(&cluster, &master, &nodes, false);
        // Its segment was stolen by another node.
        assert!(r.stolen_segments >= 1);
        assert_eq!(r.segments, 16);
    }

    #[test]
    fn stealing_soaks_up_cpu_straggler() {
        let (cluster, master, nodes) = setup(2, 40_000_000);
        let healthy = run(&cluster, &master, &nodes, false);
        // Degrade one node's CPU 4×; stealing should keep the slowdown
        // well below proportional.
        let (cluster2, master2, nodes2) = setup(2, 40_000_000);
        cluster2.set_node_speed(nodes2[0], 0.25);
        let degraded = run(&cluster2, &master2, &nodes2, false);
        assert!(degraded.makespan < healthy.makespan * 2.0,
            "straggler not absorbed: {} vs {}", degraded.makespan, healthy.makespan);
    }

    #[test]
    fn execute_matches_mapreduce_and_oracle() {
        let g = MalGen::new(MalGenConfig::small(29));
        let shards: Vec<Vec<Record>> = (0..4).map(|s| g.generate_shard(s, 4, 1_500)).collect();
        let sphere = execute_malstone_with(&shards, 6, 256, 64, SECONDS_PER_WEEK, cpu_aggregator);
        let mr = crate::hadoop::mapreduce::execute_malstone(&shards, 6, 256, 64, SECONDS_PER_WEEK);
        assert_eq!(sphere, mr);
        // And against the single-machine oracle.
        let all: Vec<Record> = shards.iter().flatten().copied().collect();
        let table = compromise_table(&all);
        let joined = bucketize(&all, &table, 256, 64, SECONDS_PER_WEEK);
        let mut oracle = MalstoneResult::zero(256, 64);
        oracle.accumulate(&joined);
        assert_eq!(sphere, oracle);
    }

    #[test]
    fn bucket_count_invariance_property() {
        crate::proptest::check("sphere bucket-count invariance", 10, |rng| {
            let g = MalGen::new(MalGenConfig::small(rng.next_u64()));
            let shards: Vec<Vec<Record>> = (0..3).map(|s| g.generate_shard(s, 3, 400)).collect();
            let a = execute_malstone_with(&shards, 1, 64, 16, SECONDS_PER_WEEK * 4, cpu_aggregator);
            let k = 2 + rng.gen_range(7) as usize;
            let b = execute_malstone_with(&shards, k, 64, 16, SECONDS_PER_WEEK * 4, cpu_aggregator);
            if a == b {
                Ok(())
            } else {
                Err(format!("bucket count {k} changed result"))
            }
        });
    }
}

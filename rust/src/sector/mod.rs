//! The Sector/Sphere substrate (paper §2.1, §3, §6; Gu & Grossman [1]).
//!
//! Sector is a distributed file system that keeps computation on the data
//! (files live as whole segments on slaves, replication is lazy and off
//! the critical path) and moves bytes with UDT. Sphere is its compute
//! engine: user-defined functions stream over local segments, hash-
//! partitioned results are pushed to *bucket* files across the cluster as
//! they are produced (compute/network overlap), and a built-in monitor
//! feeds load balancing and slow-node blacklisting.
//!
//! [`master`] holds SDFS metadata, topology-aware placement and the
//! blacklist; [`sphere`] is the two-stage UDF engine (scan+exchange,
//! aggregate) in both timing ([`sphere::SphereEngine::simulate`]) and
//! real-compute ([`sphere::execute_malstone_with`]) forms. The real
//! compute path is where the AOT-compiled JAX/Pallas histogram kernel
//! plugs in (see `runtime::MalstoneKernels::aggregator`).

pub mod master;
pub mod sphere;

pub use master::{SectorMaster, Segment};
pub use sphere::{execute_malstone_with, SphereEngine, SphereReport};

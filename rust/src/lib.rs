//! # OCT — Open Cloud Testbed reproduction
//!
//! A reproduction of *"The Open Cloud Testbed: A Wide Area Testbed for Cloud
//! Computing Utilizing High Performance Network Services"* (Grossman, Gu,
//! Sabala, Bennett, Seidman, Mambretti; 2009) as a three-layer Rust + JAX +
//! Pallas system. See `DESIGN.md` for the full inventory and the
//! paper-hardware → simulation substitution table.
//!
//! Layer map:
//! - **L3 (this crate)** — the testbed: discrete-event simulator ([`sim`]),
//!   wide-area topology and max-min fair flow network ([`net`]), TCP/UDT
//!   transport models ([`transport`]), the real GMP messaging protocol and
//!   RPC layer over UDP ([`gmp`]), the shared framework runtime
//!   ([`framework`]: storage models × slot scheduling × exchange models —
//!   the skeleton every engine and §7 interop composition instantiates),
//!   the Sector/Sphere and Hadoop substrates ([`sector`], [`hadoop`]),
//!   the MalStone benchmark suite ([`malstone`]), open-loop user-facing
//!   service traffic with SLO accounting ([`service`]), the
//!   monitoring/visualization system ([`monitor`]), and the operations
//!   plane ([`ops`]: in-band sensor → aggregator → central-service
//!   telemetry as real flows, fault injection, health state machine,
//!   and closed-loop self-healing). The simulator watches *itself*
//!   through [`trace`]: deterministic sim-time spans with Chrome-trace
//!   export plus always-on hot-path counters in every run report.
//! - **Experiment surface** — every experiment (CLI subcommands, benches,
//!   examples, integration tests) is a [`coordinator::Scenario`] built
//!   with [`coordinator::Testbed::builder`] or drawn from the named
//!   [`coordinator::registry`] sets, executed by a single
//!   [`coordinator::ScenarioRunner`] that returns a JSON-serializable
//!   [`coordinator::RunReport`] with paper references and shape checks.
//!   The dynamic-provisioning subsystem ([`coordinator::provision`])
//!   adds node imaging, dynamic lightpaths, and tenant slices: runs pay
//!   measured provisioning latency, and
//!   [`coordinator::ScenarioRunner::run_tenants`] time-shares one
//!   testbed between concurrent tenants under a
//!   [`coordinator::SliceScheduler`]'s admission control.
//! - **L2/L1 (python/, build-time only)** — the MalStone aggregation
//!   dataflow (JAX) and the one-hot-matmul histogram kernel (Pallas),
//!   AOT-lowered to HLO text and executed from [`runtime`] via PJRT
//!   (behind the `pjrt` cargo feature; a stub degrades gracefully when
//!   the `xla` dependency is unavailable).

pub mod coordinator;
pub mod framework;
pub mod gmp;
pub mod hadoop;
pub mod lint;
pub mod malstone;
pub mod monitor;
pub mod net;
pub mod ops;
pub mod proptest;
pub mod runtime;
pub mod sector;
pub mod service;
pub mod sim;
pub mod trace;
pub mod transport;
pub mod util;

/// Crate version string (matches Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

//! Single-machine MalStone ground truth.
//!
//! "This type of computation requires only a few lines of code if the data
//! is on a single machine" (paper §5) — this module is those few lines.
//! Every distributed engine and the AOT kernel path are tested against it.

use super::join::JoinedRecord;

/// Dense per-(site, week) count planes plus derived ratios.
#[derive(Debug, Clone, PartialEq)]
pub struct MalstoneResult {
    pub num_sites: usize,
    pub num_weeks: usize,
    /// Marked visits per (site, week), row-major `[site][week]`.
    pub comp: Vec<f64>,
    /// Total visits per (site, week).
    pub tot: Vec<f64>,
}

impl MalstoneResult {
    pub fn zero(num_sites: usize, num_weeks: usize) -> Self {
        MalstoneResult {
            num_sites,
            num_weeks,
            comp: vec![0.0; num_sites * num_weeks],
            tot: vec![0.0; num_sites * num_weeks],
        }
    }

    /// Accumulate joined records (the engines call this per partition).
    pub fn accumulate(&mut self, records: &[JoinedRecord]) {
        for r in records {
            if r.site < 0 {
                continue; // padding
            }
            let idx = r.site as usize * self.num_weeks + r.week as usize;
            self.tot[idx] += 1.0;
            self.comp[idx] += r.marked as f64;
        }
    }

    /// Merge a partial result (cross-worker reduction).
    pub fn merge(&mut self, other: &MalstoneResult) {
        assert_eq!((self.num_sites, self.num_weeks), (other.num_sites, other.num_weeks));
        for (a, b) in self.comp.iter_mut().zip(&other.comp) {
            *a += b;
        }
        for (a, b) in self.tot.iter_mut().zip(&other.tot) {
            *a += b;
        }
    }

    /// MalStone-A: overall ratio per site.
    pub fn ratio_a(&self) -> Vec<f64> {
        (0..self.num_sites)
            .map(|s| {
                let row = s * self.num_weeks..(s + 1) * self.num_weeks;
                let c: f64 = self.comp[row.clone()].iter().sum();
                let t: f64 = self.tot[row].iter().sum();
                if t > 0.0 {
                    c / t
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// MalStone-B: cumulative weekly ratio series per site, row-major.
    pub fn ratio_b(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.num_sites * self.num_weeks];
        for s in 0..self.num_sites {
            let (mut cc, mut ct) = (0.0, 0.0);
            for w in 0..self.num_weeks {
                let idx = s * self.num_weeks + w;
                cc += self.comp[idx];
                ct += self.tot[idx];
                out[idx] = if ct > 0.0 { cc / ct } else { 0.0 };
            }
        }
        out
    }
}

/// MalStone-A over a joined record set.
pub fn malstone_a(records: &[JoinedRecord], num_sites: usize, num_weeks: usize) -> Vec<f64> {
    let mut r = MalstoneResult::zero(num_sites, num_weeks);
    r.accumulate(records);
    r.ratio_a()
}

/// MalStone-B over a joined record set.
pub fn malstone_b(records: &[JoinedRecord], num_sites: usize, num_weeks: usize) -> Vec<f64> {
    let mut r = MalstoneResult::zero(num_sites, num_weeks);
    r.accumulate(records);
    r.ratio_b()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::malstone::join::{bucketize, compromise_table};
    use crate::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};

    fn j(site: i32, week: i32, marked: f32) -> JoinedRecord {
        JoinedRecord { site, week, marked }
    }

    #[test]
    fn hand_computed_micro_case() {
        // Site 0: 4 visits, 2 marked → A ratio 0.5.
        // Site 1: week0 1/1 marked, week1 0/1 → B = [1.0, 0.5].
        let rs = vec![
            j(0, 0, 1.0), j(0, 0, 0.0), j(0, 1, 1.0), j(0, 1, 0.0),
            j(1, 0, 1.0), j(1, 1, 0.0),
        ];
        let a = malstone_a(&rs, 2, 2);
        assert_eq!(a, vec![0.5, 0.5]);
        let b = malstone_b(&rs, 2, 2);
        assert_eq!(b, vec![0.5, 0.5, 1.0, 0.5]);
    }

    #[test]
    fn padding_rows_ignored() {
        let rs = vec![j(-1, 0, 1.0), j(0, 0, 1.0)];
        let a = malstone_a(&rs, 1, 1);
        assert_eq!(a, vec![1.0]);
    }

    #[test]
    fn empty_input_all_zero() {
        let a = malstone_a(&[], 4, 4);
        assert!(a.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn merge_equals_global() {
        crate::proptest::check("partial merge == global", 30, |rng| {
            let g = MalGen::new(MalGenConfig::small(rng.next_u64()));
            let all = g.generate_all(4, 500);
            let table = compromise_table(&all);
            let joined = bucketize(&all, &table, 64, 16, SECONDS_PER_WEEK * 4);
            let mut global = MalstoneResult::zero(64, 16);
            global.accumulate(&joined);
            // Split into 3 partitions, accumulate separately, merge.
            let mut merged = MalstoneResult::zero(64, 16);
            for chunk in joined.chunks(joined.len().div_ceil(3)) {
                let mut part = MalstoneResult::zero(64, 16);
                part.accumulate(chunk);
                merged.merge(&part);
            }
            if merged == global {
                Ok(())
            } else {
                Err("merged partials differ from global".into())
            }
        });
    }

    #[test]
    fn ratios_bounded_and_final_week_matches_a() {
        let g = MalGen::new(MalGenConfig::small(11));
        let all = g.generate_all(2, 2_000);
        let table = compromise_table(&all);
        let joined = bucketize(&all, &table, 256, 13, SECONDS_PER_WEEK * 4);
        let mut r = MalstoneResult::zero(256, 13);
        r.accumulate(&joined);
        let a = r.ratio_a();
        let b = r.ratio_b();
        for &x in a.iter().chain(b.iter()) {
            assert!((0.0..=1.0).contains(&x));
        }
        for s in 0..256 {
            let last = b[s * 13 + 12];
            assert!((last - a[s]).abs() < 1e-12);
        }
    }

    #[test]
    fn bad_sites_have_higher_ratio() {
        // The benchmark's signal: compromising sites should stand out.
        let g = MalGen::new(MalGenConfig { infect_prob: 0.5, ..MalGenConfig::small(5) });
        let all = g.generate_all(2, 30_000);
        let table = compromise_table(&all);
        let joined = bucketize(&all, &table, 256, 13, SECONDS_PER_WEEK * 4);
        let a = malstone_a(&joined, 256, 13);
        let bad_mean = crate::util::stats::mean(
            &(0..256).filter(|&s| g.is_bad_site(s as u32)).map(|s| a[s]).collect::<Vec<_>>(),
        );
        let good: Vec<f64> = (0..256)
            .filter(|&s| !g.is_bad_site(s as u32))
            .map(|s| a[s])
            .filter(|&x| x > 0.0)
            .collect();
        let good_mean = crate::util::stats::mean(&good);
        assert!(
            bad_mean > good_mean,
            "bad sites don't stand out: bad={bad_mean:.3} good={good_mean:.3}"
        );
    }
}

//! The entity-compromise join: tag every visit with whether its entity is
//! later compromised, and bucketize (site, week) for the aggregation
//! kernels.
//!
//! This is the *distributed* half of MalStone: compromise events live in
//! the same logs as visits, so every engine must group records by entity
//! (a full shuffle) before it can mark visits. In Hadoop this is the
//! map→reduce shuffle keyed by entity id; in Sphere it is a UDF bucket
//! exchange. The local (already-grouped) computation lives here and is
//! shared by the engines and the oracle so all paths agree bit-for-bit.

use std::collections::HashMap;

use super::record::Record;

/// A visit record after the join, ready for histogram aggregation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JoinedRecord {
    /// Site bucket in `[0, num_sites)`.
    pub site: i32,
    /// Week bucket in `[0, num_weeks)`.
    pub week: i32,
    /// 1.0 iff the visiting entity becomes compromised at or after this
    /// visit (the windowed attribution of TR-09-01, cumulative variant).
    pub marked: f32,
}

/// Build the entity → earliest-compromise-time table from raw records.
pub fn compromise_table(records: &[Record]) -> HashMap<u64, u64> {
    let mut t: HashMap<u64, u64> = HashMap::new();
    for r in records {
        if r.compromise_flag == 1 {
            t.entry(r.entity_id)
                .and_modify(|v| *v = (*v).min(r.timestamp))
                .or_insert(r.timestamp);
        }
    }
    t
}

/// Mark and bucketize every record against a compromise table.
///
/// `seconds_per_week` defines the week bucket; timestamps past
/// `num_weeks` clamp into the final bucket (log tails), and sites hash
/// into `num_sites` buckets with a modulus (identity when the generator's
/// site count ≤ `num_sites`).
pub fn bucketize(
    records: &[Record],
    table: &HashMap<u64, u64>,
    num_sites: u32,
    num_weeks: u32,
    seconds_per_week: u64,
) -> Vec<JoinedRecord> {
    assert!(num_sites > 0 && num_weeks > 0 && seconds_per_week > 0);
    records
        .iter()
        .map(|r| {
            let marked = match table.get(&r.entity_id) {
                Some(&tc) => f32::from(tc >= r.timestamp),
                None => 0.0,
            };
            JoinedRecord {
                site: (r.site_id % num_sites) as i32,
                week: ((r.timestamp / seconds_per_week) as u32).min(num_weeks - 1) as i32,
                marked,
            }
        })
        .collect()
}

/// Split joined records into the three dense arrays the AOT kernel takes,
/// padded with `site = -1` rows to a multiple of `batch`.
pub fn to_kernel_arrays(joined: &[JoinedRecord], batch: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    assert!(batch > 0);
    let padded = joined.len().div_ceil(batch) * batch;
    let mut site = Vec::with_capacity(padded);
    let mut week = Vec::with_capacity(padded);
    let mut marked = Vec::with_capacity(padded);
    for j in joined {
        site.push(j.site);
        week.push(j.week);
        marked.push(j.marked);
    }
    site.resize(padded, -1);
    week.resize(padded, 0);
    marked.resize(padded, 0.0);
    (site, week, marked)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn visit(entity: u64, site: u32, ts: u64) -> Record {
        Record { event_id: ts, timestamp: ts, site_id: site, compromise_flag: 0, entity_id: entity }
    }

    fn comp(entity: u64, site: u32, ts: u64) -> Record {
        Record { event_id: ts, timestamp: ts, site_id: site, compromise_flag: 1, entity_id: entity }
    }

    #[test]
    fn table_takes_earliest_compromise() {
        let rs = vec![comp(1, 0, 500), comp(1, 0, 100), visit(2, 1, 50)];
        let t = compromise_table(&rs);
        assert_eq!(t.get(&1), Some(&100));
        assert_eq!(t.get(&2), None);
    }

    #[test]
    fn visits_before_compromise_are_marked() {
        let rs = vec![visit(1, 3, 100), comp(1, 5, 200), visit(1, 3, 300)];
        let t = compromise_table(&rs);
        let j = bucketize(&rs, &t, 16, 8, 100);
        // Visit at t=100 (before compromise at 200): marked.
        assert_eq!(j[0].marked, 1.0);
        // The compromise record itself is a visit at the moment of
        // compromise: marked (tc >= ts).
        assert_eq!(j[1].marked, 1.0);
        // Visit after compromise: not attributed.
        assert_eq!(j[2].marked, 0.0);
    }

    #[test]
    fn week_bucketing_and_clamp() {
        let rs = vec![visit(1, 0, 0), visit(1, 0, 250), visit(1, 0, 10_000)];
        let t = HashMap::new();
        let j = bucketize(&rs, &t, 4, 4, 100);
        assert_eq!(j[0].week, 0);
        assert_eq!(j[1].week, 2);
        assert_eq!(j[2].week, 3); // clamped into last bucket
    }

    #[test]
    fn site_modulus() {
        let rs = vec![visit(1, 21, 0)];
        let j = bucketize(&rs, &HashMap::new(), 16, 4, 100);
        assert_eq!(j[0].site, 5);
    }

    #[test]
    fn kernel_arrays_pad_to_batch() {
        let j = vec![JoinedRecord { site: 1, week: 2, marked: 1.0 }; 5];
        let (s, w, m) = to_kernel_arrays(&j, 4);
        assert_eq!(s.len(), 8);
        assert_eq!(&s[..5], &[1, 1, 1, 1, 1]);
        assert_eq!(&s[5..], &[-1, -1, -1]);
        assert_eq!(w[7], 0);
        assert_eq!(m[6], 0.0);
    }

    #[test]
    fn join_is_order_insensitive_property() {
        crate::proptest::check("join order-insensitive", 30, |rng| {
            let mut rs = Vec::new();
            for i in 0..200u64 {
                let flag = rng.chance(0.1);
                rs.push(Record {
                    event_id: i,
                    timestamp: rng.gen_range(1000),
                    site_id: rng.gen_range(16) as u32,
                    compromise_flag: u8::from(flag),
                    entity_id: rng.gen_range(20),
                });
            }
            let t1 = compromise_table(&rs);
            let mut shuffled = rs.clone();
            rng.shuffle(&mut shuffled);
            let t2 = compromise_table(&shuffled);
            if t1 != t2 {
                return Err("table differs under permutation".into());
            }
            // Per-record marking only depends on the table, so histogram
            // totals are permutation-invariant too.
            Ok(())
        });
    }
}

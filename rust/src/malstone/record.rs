//! The 100-byte MalStone record and its binary codec.
//!
//! Paper §5: `| Event ID | Timestamp | Site ID | Compromise Flag |
//! Entity ID |`, with "10 billion, 100 billion or 1 trillion 100-byte
//! records (so that there is 1 TB, 10 TB and 100 TB of data in total)".
//! Fields are little-endian; the remainder of the 100 bytes is padding
//! (MalGen fills it with a deterministic pattern so files are realistic).

/// Exactly the paper's record size.
pub const RECORD_BYTES: usize = 100;

const MAGIC: u16 = 0x4D53; // "MS"

/// One visit (or compromise) event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Record {
    pub event_id: u64,
    /// Seconds since the epoch of the modeled window.
    pub timestamp: u64,
    pub site_id: u32,
    /// 1 iff this visit is the moment the entity became compromised.
    pub compromise_flag: u8,
    pub entity_id: u64,
}

impl Record {
    /// Serialize into a 100-byte buffer.
    pub fn encode(&self) -> [u8; RECORD_BYTES] {
        let mut b = [0u8; RECORD_BYTES];
        b[0..2].copy_from_slice(&MAGIC.to_le_bytes());
        b[2..10].copy_from_slice(&self.event_id.to_le_bytes());
        b[10..18].copy_from_slice(&self.timestamp.to_le_bytes());
        b[18..22].copy_from_slice(&self.site_id.to_le_bytes());
        b[22] = self.compromise_flag;
        b[23..31].copy_from_slice(&self.entity_id.to_le_bytes());
        // Deterministic padding derived from the event id (keeps records
        // incompressible-ish like real logs, and detects torn reads).
        let mut x = self.event_id.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        for c in b[31..].iter_mut() {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            *c = x as u8;
        }
        b
    }

    /// Parse a 100-byte buffer. Fails on bad magic or flag.
    pub fn decode(b: &[u8]) -> Result<Record, String> {
        if b.len() != RECORD_BYTES {
            return Err(format!("record must be {RECORD_BYTES} bytes, got {}", b.len()));
        }
        let magic = u16::from_le_bytes([b[0], b[1]]);
        if magic != MAGIC {
            return Err(format!("bad record magic {magic:#x}"));
        }
        let flag = b[22];
        if flag > 1 {
            return Err(format!("bad compromise flag {flag}"));
        }
        Ok(Record {
            event_id: u64::from_le_bytes(b[2..10].try_into().unwrap()),
            timestamp: u64::from_le_bytes(b[10..18].try_into().unwrap()),
            site_id: u32::from_le_bytes(b[18..22].try_into().unwrap()),
            compromise_flag: flag,
            entity_id: u64::from_le_bytes(b[23..31].try_into().unwrap()),
        })
    }

    /// Encode a batch into a contiguous byte buffer.
    pub fn encode_batch(records: &[Record]) -> Vec<u8> {
        let mut out = Vec::with_capacity(records.len() * RECORD_BYTES);
        for r in records {
            out.extend_from_slice(&r.encode());
        }
        out
    }

    /// Decode a contiguous buffer of records.
    pub fn decode_batch(bytes: &[u8]) -> Result<Vec<Record>, String> {
        if bytes.len() % RECORD_BYTES != 0 {
            return Err(format!("buffer length {} not a multiple of {RECORD_BYTES}", bytes.len()));
        }
        bytes.chunks_exact(RECORD_BYTES).map(Record::decode).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Record {
        Record {
            event_id: 42,
            timestamp: 1_234_567,
            site_id: 77,
            compromise_flag: 1,
            entity_id: 987_654_321,
        }
    }

    #[test]
    fn roundtrip() {
        let r = sample();
        let b = r.encode();
        assert_eq!(b.len(), RECORD_BYTES);
        assert_eq!(Record::decode(&b).unwrap(), r);
    }

    #[test]
    fn batch_roundtrip() {
        let rs: Vec<Record> = (0..17)
            .map(|i| Record {
                event_id: i,
                timestamp: i * 3600,
                site_id: (i % 5) as u32,
                compromise_flag: (i % 2) as u8,
                entity_id: i * 7,
            })
            .collect();
        let buf = Record::encode_batch(&rs);
        assert_eq!(buf.len(), 17 * RECORD_BYTES);
        assert_eq!(Record::decode_batch(&buf).unwrap(), rs);
    }

    #[test]
    fn rejects_corruption() {
        let mut b = sample().encode();
        b[0] = 0; // magic
        assert!(Record::decode(&b).is_err());
        let mut b2 = sample().encode();
        b2[22] = 9; // flag
        assert!(Record::decode(&b2).is_err());
        assert!(Record::decode(&[0u8; 50]).is_err());
        assert!(Record::decode_batch(&[0u8; 150]).is_err());
    }

    #[test]
    fn padding_is_deterministic() {
        assert_eq!(sample().encode(), sample().encode());
        // Different event ids give different padding.
        let mut other = sample();
        other.event_id += 1;
        assert_ne!(sample().encode()[31..], other.encode()[31..]);
    }

    #[test]
    fn roundtrip_property() {
        crate::proptest::check("record codec roundtrip", 100, |rng| {
            let r = Record {
                event_id: rng.next_u64(),
                timestamp: rng.next_u64() >> 20,
                site_id: rng.next_u64() as u32,
                compromise_flag: (rng.next_u64() % 2) as u8,
                entity_id: rng.next_u64(),
            };
            let back = Record::decode(&r.encode()).map_err(|e| e.to_string())?;
            if back == r {
                Ok(())
            } else {
                Err(format!("{back:?} != {r:?}"))
            }
        });
    }
}

//! The MalStone benchmark suite (paper §5; OCC TR-09-01).
//!
//! MalStone is a stylized "drive-by exploit" analytic: log records of
//! entities visiting sites, where visiting certain sites sometimes
//! compromises the visitor. For each site (and, in MalStone-B, for each
//! week) compute the fraction of visits whose entity subsequently becomes
//! compromised — a computation that is a few lines on one machine but a
//! demanding shuffle/aggregation at 10⁹–10¹² records on a cloud.
//!
//! - [`record`]: the 100-byte record codec
//!   (`| Event ID | Timestamp | Site ID | Compromise Flag | Entity ID |`).
//! - [`malgen`]: MalGen, the deterministic sharded data generator.
//! - [`join`]: the entity-compromise join that tags each visit with its
//!   `marked` bit — the shuffle-heavy half of the benchmark that the
//!   distributed engines move over the network.
//! - [`oracle`]: single-machine ground truth for MalStone-A and B.
//! - [`scale`]: paper-scale workload descriptors (10 B records / 1 TB …).

pub mod join;
pub mod malgen;
pub mod oracle;
pub mod record;
pub mod scale;

pub use join::{bucketize, JoinedRecord};
pub use malgen::{MalGen, MalGenConfig};
pub use oracle::{malstone_a, malstone_b, MalstoneResult};
pub use record::{Record, RECORD_BYTES};

//! Paper-scale workload descriptors.
//!
//! The simulator runs engines against a *described* workload (record and
//! byte counts per node) while correctness runs use real generated records
//! at laptop scale. These descriptors encode the exact setups of the
//! paper's experiments.

use super::record::RECORD_BYTES;

/// A MalStone workload at some scale.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub total_records: u64,
    /// Nodes that hold/generate the data (MalGen shards).
    pub nodes: usize,
}

impl Workload {
    pub fn new(name: &str, total_records: u64, nodes: usize) -> Self {
        assert!(nodes > 0);
        Workload { name: name.to_string(), total_records, nodes }
    }

    /// Table 1: "500 million 100-byte records on 20 nodes (for a total of
    /// 10 billion records or 1 TB of data)".
    pub fn table1() -> Self {
        Workload::new("table1-10B", 10_000_000_000, 20)
    }

    /// Table 2: "15 billion \[records\] on 28 nodes".
    pub fn table2() -> Self {
        Workload::new("table2-15B", 15_000_000_000, 28)
    }

    /// The canonical larger MalStone scales (§5).
    pub fn malstone_100b() -> Self {
        Workload::new("malstone-100B", 100_000_000_000, 100)
    }

    pub fn malstone_1t() -> Self {
        Workload::new("malstone-1T", 1_000_000_000_000, 250)
    }

    pub fn records_per_node(&self) -> u64 {
        self.total_records.div_ceil(self.nodes as u64)
    }

    pub fn bytes_total(&self) -> u64 {
        self.total_records * RECORD_BYTES as u64
    }

    pub fn bytes_per_node(&self) -> u64 {
        self.records_per_node() * RECORD_BYTES as u64
    }

    /// Scale every count down by `factor` (for quick sanity sweeps).
    pub fn scaled_down(&self, factor: u64) -> Workload {
        assert!(factor > 0);
        Workload {
            name: format!("{}/÷{}", self.name, factor),
            total_records: (self.total_records / factor).max(1),
            nodes: self.nodes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_is_one_terabyte() {
        let w = Workload::table1();
        assert_eq!(w.bytes_total(), 1_000_000_000_000);
        assert_eq!(w.records_per_node(), 500_000_000);
    }

    #[test]
    fn table2_counts() {
        let w = Workload::table2();
        assert_eq!(w.total_records, 15_000_000_000);
        assert_eq!(w.nodes, 28);
        // 15B/28 doesn't divide evenly; per-node rounds up.
        assert_eq!(w.records_per_node(), 535_714_286);
    }

    #[test]
    fn scaling_down() {
        let w = Workload::table1().scaled_down(1000);
        assert_eq!(w.total_records, 10_000_000);
        assert_eq!(w.nodes, 20);
    }
}

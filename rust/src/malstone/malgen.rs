//! MalGen: the MalStone data generator (paper §5, code.google.com/p/malgen).
//!
//! Generates visit logs with the statistical structure the benchmark
//! needs: site popularity follows a power law (a few "hot" sites draw most
//! traffic), a small fraction of sites are *compromising*, and a visit to
//! a compromising site infects the visiting entity with some probability —
//! the visit that infects carries `compromise_flag = 1` (the drive-by
//! exploit moment). Generation is **sharded and deterministic**: shard `k`
//! of `n` is reproducible in isolation from the seed, which is how the
//! real MalGen generated 500M records on each of 20 nodes concurrently.

use crate::util::rng::{Rng, Zipf};

use super::record::Record;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct MalGenConfig {
    pub seed: u64,
    pub num_sites: u32,
    pub num_entities: u64,
    /// Modeled time range, in weeks (Table 1 runs use ~1 year of logs).
    pub weeks: u32,
    /// Zipf exponent for site popularity.
    pub zipf_s: f64,
    /// Fraction of sites that can compromise visitors.
    pub bad_site_frac: f64,
    /// Probability a visit to a bad site compromises the entity.
    pub infect_prob: f64,
}

impl Default for MalGenConfig {
    fn default() -> Self {
        MalGenConfig {
            seed: DEFAULT_SEED,
            num_sites: 256,
            num_entities: 10_000,
            weeks: 52,
            zipf_s: 1.1,
            bad_site_frac: 0.02,
            infect_prob: 0.2,
        }
    }
}

/// Default generator seed ("OCT" on a hex keypad).
const DEFAULT_SEED: u64 = 0x0C7_0C7;

/// Sharded deterministic generator.
#[derive(Debug, Clone)]
pub struct MalGen {
    cfg: MalGenConfig,
    zipf: Zipf,
}

pub const SECONDS_PER_WEEK: u64 = 7 * 24 * 3600;

impl MalGen {
    pub fn new(cfg: MalGenConfig) -> Self {
        let zipf = Zipf::new(cfg.num_sites as usize, cfg.zipf_s);
        MalGen { cfg, zipf }
    }

    pub fn config(&self) -> &MalGenConfig {
        &self.cfg
    }

    /// Is `site` one of the compromising sites? Deterministic in the seed.
    pub fn is_bad_site(&self, site: u32) -> bool {
        // Hash site id with the seed; compare against the bad fraction.
        let mut x = (site as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ self.cfg.seed;
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51AFD7ED558CCD);
        x ^= x >> 33;
        (x as f64 / u64::MAX as f64) < self.cfg.bad_site_frac
    }

    /// Generate shard `shard` of `num_shards`, containing `n` records.
    /// Shards are independent streams: entity ids are partitioned across
    /// shards so the compromise logic stays self-consistent per shard.
    pub fn generate_shard(&self, shard: u64, num_shards: u64, n: usize) -> Vec<Record> {
        assert!(shard < num_shards);
        let mut rng = Rng::new(self.cfg.seed ^ shard.wrapping_mul(0xA076_1D64_78BD_642F));
        let mut out = Vec::with_capacity(n);
        let entities_per_shard = (self.cfg.num_entities / num_shards).max(1);
        let entity_base = shard * entities_per_shard;
        let horizon = self.cfg.weeks as u64 * SECONDS_PER_WEEK;
        for i in 0..n {
            let entity_id = entity_base + rng.gen_range(entities_per_shard);
            let site_id = self.zipf.sample(&mut rng) as u32;
            let timestamp = rng.gen_range(horizon.max(1));
            let compromise_flag =
                u8::from(self.is_bad_site(site_id) && rng.chance(self.cfg.infect_prob));
            out.push(Record {
                event_id: shard << 40 | i as u64,
                timestamp,
                site_id,
                compromise_flag,
                entity_id,
            });
        }
        out
    }

    /// Convenience: all shards concatenated (small scales only).
    pub fn generate_all(&self, num_shards: u64, per_shard: usize) -> Vec<Record> {
        (0..num_shards).flat_map(|s| self.generate_shard(s, num_shards, per_shard)).collect()
    }
}

impl MalGenConfig {
    /// Small config for tests/examples: quick but statistically non-trivial.
    pub fn small(seed: u64) -> Self {
        MalGenConfig { seed, num_entities: 2_000, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> MalGen {
        MalGen::new(MalGenConfig::small(7))
    }

    #[test]
    fn shards_are_deterministic() {
        let g = gen();
        assert_eq!(g.generate_shard(3, 8, 500), g.generate_shard(3, 8, 500));
    }

    #[test]
    fn shards_are_distinct() {
        let g = gen();
        assert_ne!(g.generate_shard(0, 8, 100), g.generate_shard(1, 8, 100));
    }

    #[test]
    fn fields_in_range() {
        let g = gen();
        let horizon = g.config().weeks as u64 * SECONDS_PER_WEEK;
        for r in g.generate_shard(0, 4, 2_000) {
            assert!(r.site_id < g.config().num_sites);
            assert!(r.timestamp < horizon);
            assert!(r.entity_id < g.config().num_entities);
            assert!(r.compromise_flag <= 1);
        }
    }

    #[test]
    fn popularity_is_skewed() {
        let g = gen();
        let rs = g.generate_shard(0, 1, 20_000);
        let mut counts = vec![0u32; g.config().num_sites as usize];
        for r in &rs {
            counts[r.site_id as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let median = {
            let mut c = counts.clone();
            c.sort();
            c[c.len() / 2]
        };
        assert!(max > median * 10, "power law missing: max={max} median={median}");
    }

    #[test]
    fn compromises_only_on_bad_sites() {
        let g = gen();
        for r in g.generate_all(4, 5_000) {
            if r.compromise_flag == 1 {
                assert!(g.is_bad_site(r.site_id), "flag on good site {}", r.site_id);
            }
        }
    }

    #[test]
    fn some_compromises_exist() {
        let g = gen();
        let n = g.generate_all(4, 5_000).iter().filter(|r| r.compromise_flag == 1).count();
        assert!(n > 0, "no compromises generated — benchmark would be vacuous");
    }

    #[test]
    fn bad_site_fraction_approx() {
        let g = MalGen::new(MalGenConfig { num_sites: 10_000, ..MalGenConfig::small(3) });
        let bad = (0..10_000u32).filter(|&s| g.is_bad_site(s)).count() as f64 / 10_000.0;
        assert!((bad - 0.02).abs() < 0.01, "bad fraction {bad}");
    }

    #[test]
    fn event_ids_unique_across_shards() {
        let g = gen();
        let all = g.generate_all(4, 1_000);
        let mut ids: Vec<u64> = all.iter().map(|r| r.event_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), all.len());
    }
}

//! Bench: the sharded parallel engine vs sequential execution on the
//! mega-churn registry scenario.
//!
//! Two assertions, in order of importance:
//!
//! 1. **Byte-identical reports.** The same scaled-down `mega-churn` set
//!    runs through the [`ScenarioRunner`] with `--threads 1` and
//!    `--threads N` (default 4). Both take the *same* sharded driver
//!    (the gate is on scenario shape, not thread count), so the
//!    conservative lookahead protocol — not luck — must make the two
//!    [`RunReport`] JSON serializations identical byte for byte.
//!    This always gates.
//! 2. **Wall-clock speedup.** The N-thread run must beat the 1-thread
//!    run by at least `OCT_PAR_MIN_SPEEDUP` (default 2.0; CI sets a
//!    lower floor on small shared runners — the byte-identity check is
//!    the blocking part there). Set it to 0 to skip the gate entirely.
//!
//! Writes the machine-readable result to `BENCH_engine_parallel.json`
//! at the repo root, next to the other BENCH artifacts.
//!
//! Env knobs: `OCT_PAR_DIV` (divides the registry workload; default 2 →
//! 200k transfers / 50k slots), `OCT_PAR_THREADS` (default 4),
//! `OCT_PAR_MIN_SPEEDUP` (default 2.0; 0 disables the speedup gate).

use std::time::Instant;

use oct::coordinator::{find_set, RunReport, ScenarioRunner};
use oct::util::json::{obj, Json};

fn env_or(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_or_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

struct Leg {
    json: String,
    wall: f64,
    reports: Vec<RunReport>,
}

/// One full pass over the set at a fixed thread count. The report JSON
/// deliberately excludes wall-clock stats, so `json` is comparable
/// across legs; the leg's own wall time is measured around the run.
fn run_leg(div: u64, threads: usize) -> Leg {
    let set = find_set("mega-churn").expect("mega-churn set registered").scaled_down(div);
    let runner = ScenarioRunner::new().with_threads(threads);
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = Instant::now();
    let reports = runner.run_set(&set);
    let wall = t0.elapsed().as_secs_f64();
    let json =
        reports.iter().map(|r| r.to_json().to_string()).collect::<Vec<_>>().join("\n");
    Leg { json, wall, reports }
}

fn write_bench_json(div: u64, threads: u64, seq: &Leg, par: &Leg, speedup: f64) {
    let events_per_sec =
        par.reports[0].wall.map_or(Json::Null, |w| Json::Num(w.events_per_sec));
    // The self-profiler's hot-path counters ride along so benchcmp can
    // attribute a wall-time regression (e.g. a recompute-scope blowup
    // shows up as refill/dirty growth at flat event counts). Counters
    // are engine-deterministic; the sched ratios depend on the host.
    let prof = &par.reports[0].profile;
    let (stalled_rounds, lookahead_util) = match &prof.sched {
        Some(s) => (Json::Num(s.stalled_rounds as f64), Json::Num(s.lookahead_utilization())),
        None => (Json::Null, Json::Null),
    };
    let doc = obj(vec![
        ("bench", Json::Str("engine_parallel".into())),
        ("scale_div", Json::Num(div as f64)),
        ("transfers", Json::Num(seq.reports[0].total_records as f64)),
        ("threads", Json::Num(threads as f64)),
        ("sequential_wall_secs", Json::Num(seq.wall)),
        ("parallel_wall_secs", Json::Num(par.wall)),
        ("speedup_parallel_vs_sequential", Json::Num(speedup)),
        ("events_per_sec_parallel", events_per_sec),
        ("reports_byte_identical", Json::Bool(seq.json == par.json)),
        ("profile_events", Json::Num(prof.events as f64)),
        ("profile_timers_armed", Json::Num(prof.timers_armed as f64)),
        ("profile_timers_cancelled", Json::Num(prof.timers_cancelled as f64)),
        ("profile_channel_messages", Json::Num(prof.channel_messages as f64)),
        ("profile_refill_components", Json::Num(prof.refill_components as f64)),
        ("profile_dirty_links", Json::Num(prof.dirty_links as f64)),
        ("profile_stalled_rounds", stalled_rounds),
        ("profile_lookahead_utilization", lookahead_util),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_engine_parallel.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}

fn main() {
    let div = env_or("OCT_PAR_DIV", 2).max(1);
    let threads = env_or("OCT_PAR_THREADS", 4).max(2);
    let min_speedup = env_or_f64("OCT_PAR_MIN_SPEEDUP", 2.0);

    println!("=== engine parallel: mega-churn registry scenario at 1/{div} scale ===");
    let seq = run_leg(div, 1);
    println!("sequential (1 thread)    {:>8.2}s wall", seq.wall);
    let par = run_leg(div, threads as usize);
    println!("parallel  ({threads} threads)    {:>8.2}s wall", par.wall);

    // The hard requirement first: any thread count, same bytes.
    assert_eq!(
        seq.json, par.json,
        "sequential and {threads}-thread runs must produce byte-identical reports"
    );
    println!("reports byte-identical across thread counts");

    // The registry's own shape criteria hold (one leg suffices — the
    // reports are byte-identical).
    let set = find_set("mega-churn").unwrap().scaled_down(div);
    for c in set.run_checks(&seq.reports) {
        assert!(c.pass, "{}: {}", c.name, c.detail);
    }

    let speedup = seq.wall / par.wall.max(1e-9);
    write_bench_json(div, threads, &seq, &par, speedup);
    println!("speedup: {speedup:.2}× at {threads} threads");
    if min_speedup > 0.0 {
        assert!(
            speedup >= min_speedup,
            "parallel engine too slow: {speedup:.2}× < {min_speedup:.1}× at {threads} threads"
        );
    } else {
        println!("speedup gate disabled (OCT_PAR_MIN_SPEEDUP=0)");
    }
    println!("engine parallel OK");
}

//! Bench: the Figure-3 monitoring system's overhead — sampling ingest
//! rate over the full 128-node testbed and heatmap render cost. The
//! monitor must be cheap enough to run continuously (paper §3: "simple
//! but effective").

use oct::monitor::heatmap::Metric;
use oct::monitor::{render_heatmap, Monitor};
use oct::net::{Cluster, FlowNet, Topology};
use oct::sim::Engine;
use std::time::Instant;

fn main() {
    let cluster = Cluster::new(Topology::oct_2009());
    let topo = cluster.topo.clone();
    let mon = Monitor::new(topo.clone(), 1.0);
    let mut eng = Engine::new();
    // Put live traffic on the fabric so sampling reads real counters.
    for i in 0..64 {
        let a = topo.racks[i % 4].nodes[i % 32];
        let b = topo.racks[(i + 1) % 4].nodes[(i + 7) % 32];
        FlowNet::start(&cluster.net, &mut eng, topo.path(a, b), 1e12, f64::INFINITY, |_| {});
    }
    eng.run_until(1.0);

    // Ingest: full-testbed samples per wall second.
    let samples = 2000;
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = Instant::now();
    for i in 0..samples {
        eng.run_until(1.0 + i as f64);
        mon.borrow_mut().sample_all(&eng, &cluster.net, &cluster.pools);
    }
    let dt = t0.elapsed().as_secs_f64();
    let node_samples = samples as f64 * topo.num_nodes() as f64;
    println!("=== monitoring ingest (128 nodes, 64 live flows) ===");
    println!(
        "{samples} testbed sweeps in {:.2}s → {:.0} sweeps/s ({:.2}M node-samples/s)",
        dt,
        samples as f64 / dt,
        node_samples / dt / 1e6
    );
    assert!(samples as f64 / dt > 50.0, "monitor sampling too slow to run at 1 Hz");

    // Render: Figure 3 frames per second (ANSI + plain).
    for (ansi, label) in [(true, "ansi"), (false, "plain")] {
        let frames = 2000;
        // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
        let t1 = Instant::now();
        let mut bytes = 0usize;
        for _ in 0..frames {
            bytes += render_heatmap(&mon.borrow(), Metric::Network, ansi).len();
        }
        let rdt = t1.elapsed().as_secs_f64();
        println!(
            "render {label}: {:.0} frames/s ({:.0} KB/frame)",
            frames as f64 / rdt,
            bytes as f64 / frames as f64 / 1024.0
        );
    }

    // JSON export cost (the web feed).
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t2 = Instant::now();
    let frames = 1000;
    let mut total = 0usize;
    for _ in 0..frames {
        total += mon.borrow().frame_json(eng.now()).to_string().len();
    }
    println!(
        "json export: {:.0} frames/s ({} bytes/frame)",
        frames as f64 / t2.elapsed().as_secs_f64(),
        total / frames
    );
    println!("fig3_monitoring OK");
}

//! Bench: regenerate **Table 1** — MalStone-A/B across Hadoop MapReduce,
//! Hadoop Streaming, and Sector/Sphere on the 20-node OCT layout — via
//! the scenario registry and `ScenarioRunner`.
//!
//! `OCT_BENCH_SCALE` divides the 10B-record workload (default 20; use 1
//! for full paper scale — the simulation is shape-preserving in scale).
//! Asserts the set's shape checks: ordering, Sector≫Hadoop factor, B > A.

use oct::coordinator::{find_set, format_checks, format_reports, ScenarioRunner};

fn main() {
    let scale: u64 =
        std::env::var("OCT_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let set = find_set("table1").expect("table1 set registered").scaled_down(scale);
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = std::time::Instant::now();
    let reports = ScenarioRunner::new().run_all(&set.scenarios);
    let wall = t0.elapsed().as_secs_f64();
    println!("=== Table 1: MalStone on 10B records / 20 nodes (scale 1/{scale}) ===");
    print!("{}", format_reports(&reports));
    println!("simulated in {wall:.1}s wall");

    // Shape assertions (the reproduction criteria from DESIGN.md §3),
    // evaluated by the set's registered check.
    let checks = set.run_checks(&reports);
    print!("{}", format_checks(&checks));
    // Look reports up by the fields they carry rather than by position,
    // so registry reordering cannot silently skew the printed factors.
    let sim = |fw: &str, variant: &str| {
        reports
            .iter()
            .find(|r| r.framework == fw && r.variant == variant)
            .unwrap_or_else(|| panic!("missing report {fw}/{variant}"))
            .simulated_secs
    };
    let factor_a = sim("hadoop-mapreduce", "A") / sim("sector-sphere", "A");
    let factor_b = sim("hadoop-mapreduce", "B") / sim("sector-sphere", "B");
    println!(
        "sector vs hadoop-MR speedup: A {factor_a:.1}× (paper 13.5×), B {factor_b:.1}× (paper 19.2×)"
    );
    for r in &reports {
        if let Some(ratio) = r.paper_ratio() {
            println!("  {}: within {:.0}% of paper", r.scenario, (ratio - 1.0).abs() * 100.0);
        }
    }
    assert!(checks.iter().all(|c| c.pass), "table1 shape lost:\n{}", format_checks(&checks));
    println!("table1 shape OK");
}

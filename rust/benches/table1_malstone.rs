//! Bench: regenerate **Table 1** — MalStone-A/B across Hadoop MapReduce,
//! Hadoop Streaming, and Sector/Sphere on the 20-node OCT layout.
//!
//! `OCT_BENCH_SCALE` divides the 10B-record workload (default 20; use 1
//! for full paper scale — the simulation is shape-preserving in scale).
//! Asserts the paper's shape: ordering, Sector≫Hadoop factor, B > A.

use oct::coordinator::experiment::{format_table1, run_table1};

fn main() {
    let scale: u64 = std::env::var("OCT_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let t0 = std::time::Instant::now();
    let rows = run_table1(scale);
    let wall = t0.elapsed().as_secs_f64();
    println!("=== Table 1: MalStone on 10B records / 20 nodes (scale 1/{scale}) ===");
    print!("{}", format_table1(&rows));
    println!("simulated in {wall:.1}s wall");

    // Shape assertions (the reproduction criteria from DESIGN.md §3).
    let (mr, st, sp) = (&rows[0], &rows[1], &rows[2]);
    assert!(sp.a_secs < st.a_secs && st.a_secs < mr.a_secs, "A ordering");
    assert!(sp.b_secs < st.b_secs && st.b_secs < mr.b_secs, "B ordering");
    let factor_a = mr.a_secs / sp.a_secs;
    let factor_b = mr.b_secs / sp.b_secs;
    println!("sector vs hadoop-MR speedup: A {factor_a:.1}× (paper 13.5×), B {factor_b:.1}× (paper 19.2×)");
    assert!(factor_a > 5.0 && factor_b > 5.0, "sector speedup shape lost");
    for r in &rows {
        assert!(r.b_secs > r.a_secs, "{}: MalStone-B must cost more than A", r.framework);
        let rel = (r.a_secs - r.paper_a).abs() / r.paper_a;
        println!("  {}: A within {:.0}% of paper, B within {:.0}%", r.framework,
            rel * 100.0, (r.b_secs - r.paper_b).abs() / r.paper_b * 100.0);
    }
    println!("table1 shape OK");
}

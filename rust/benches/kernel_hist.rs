//! Bench: the AOT JAX/Pallas MalStone histogram through PJRT — the
//! three-layer hot path — vs the pure-Rust accumulator baseline.
//!
//! Requires `make artifacts`.

use oct::malstone::join::JoinedRecord;
use oct::malstone::oracle::MalstoneResult;
use oct::runtime::{default_artifact_dir, MalstoneKernels};
use oct::util::Rng;
use std::time::Instant;

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let k = match MalstoneKernels::load(&dir) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("cannot execute kernels: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "PJRT platform: {}; batch {}, planes {}×{}",
        k.platform(),
        k.meta.batch,
        k.meta.num_sites,
        k.meta.num_weeks
    );

    let n = 1_000_000usize;
    let mut rng = Rng::new(11);
    let joined: Vec<JoinedRecord> = (0..n)
        .map(|_| JoinedRecord {
            site: rng.gen_range(k.meta.num_sites as u64) as i32,
            week: rng.gen_range(k.meta.num_weeks as u64) as i32,
            marked: f32::from(rng.chance(0.25)),
        })
        .collect();

    // Warmup + correctness.
    let planes = k.hist(&joined[..k.meta.batch]).unwrap();
    let mut want = MalstoneResult::zero(k.meta.num_sites, k.meta.num_weeks);
    want.accumulate(&joined[..k.meta.batch]);
    assert_eq!(planes, want, "kernel diverged from oracle");

    // PJRT throughput.
    let reps = 3;
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ = k.hist(&joined).unwrap();
    }
    let pjrt_dt = t0.elapsed().as_secs_f64() / reps as f64;

    // Pure-Rust baseline.
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t1 = Instant::now();
    for _ in 0..reps {
        let mut r = MalstoneResult::zero(k.meta.num_sites, k.meta.num_weeks);
        r.accumulate(&joined);
        std::hint::black_box(&r);
    }
    let rust_dt = t1.elapsed().as_secs_f64() / reps as f64;

    println!("=== {n} records/run, {reps} runs ===");
    println!(
        "pjrt pallas-hist: {:.1} ms  ({:.2}M rec/s, {} executions)",
        pjrt_dt * 1e3,
        n as f64 / pjrt_dt / 1e6,
        k.hist_calls.borrow()
    );
    println!("rust scatter-add: {:.1} ms  ({:.2}M rec/s)", rust_dt * 1e3, n as f64 / rust_dt / 1e6);
    println!(
        "note: interpret=True Pallas on CPU-PJRT measures the *dataflow*, not TPU \
         perf; DESIGN.md §Perf estimates MXU utilization from the BlockSpec."
    );
    println!("kernel_hist OK");
}

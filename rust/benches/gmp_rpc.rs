//! Bench: GMP messaging + RPC (paper §4). Real UDP loopback round-trips
//! (latency percentiles, throughput) and the connectionless-vs-TCP
//! control-message model across testbed RTTs.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use oct::gmp::rpc::Handler;
use oct::gmp::{GmpConfig, GmpEndpoint, RpcClient, RpcServer};
use oct::transport::control_message_latency;
use oct::util::stats;

fn main() {
    let iters = 3000usize;
    let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
    let addr = ep.local_addr();
    let mut handlers: HashMap<String, Handler> = HashMap::new();
    handlers.insert("ping".into(), Box::new(|b: &[u8]| b.to_vec()));
    let _srv = RpcServer::start(ep, handlers);
    let client = RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());

    for _ in 0..200 {
        client.call(addr, "ping", b"warmup", Duration::from_secs(1)).unwrap();
    }
    let mut lat = Vec::with_capacity(iters);
    // simlint: allow(SIM002) — real UDP loopback latency; wall-clock is the measurement
    let t0 = Instant::now();
    for _ in 0..iters {
        // simlint: allow(SIM002) — real UDP loopback latency; wall-clock is the measurement
        let t = Instant::now();
        client.call(addr, "ping", &[7u8; 32], Duration::from_secs(1)).unwrap();
        lat.push(t.elapsed().as_secs_f64() * 1e6);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("=== GMP RPC, real UDP loopback, {iters} calls ===");
    println!(
        "mean {:.1} µs  p50 {:.1} µs  p99 {:.1} µs  throughput {:.0} rpc/s",
        stats::mean(&lat),
        stats::percentile(&lat, 50.0),
        stats::percentile(&lat, 99.0),
        iters as f64 / wall
    );
    assert!(stats::percentile(&lat, 50.0) < 1000.0, "loopback RPC p50 suspiciously slow");

    // Reliability machinery under loss: retransmits happen, delivery holds.
    let lossy = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
    lossy.set_fault(oct::gmp::FaultSpec { drop_every: 5, dup_every: 7 });
    let lossy_client = RpcClient::new(lossy);
    // simlint: allow(SIM002) — real UDP loopback latency; wall-clock is the measurement
    let t1 = Instant::now();
    let n_lossy = 300;
    for i in 0..n_lossy {
        lossy_client.call(addr, "ping", format!("{i}").as_bytes(), Duration::from_secs(2)).unwrap();
    }
    println!(
        "under 20% drop + 14% dup: {n_lossy} calls in {:.2}s (exactly-once held)",
        t1.elapsed().as_secs_f64()
    );

    println!("\n=== modeled control message: connectionless GMP vs TCP (§4) ===");
    println!("{:>10} {:>10} {:>10} {:>8}", "RTT", "GMP", "TCP", "saving");
    for rtt_ms in [0.1, 1.0, 22.0, 58.0, 75.0] {
        let rtt = rtt_ms / 1e3;
        let g = control_message_latency(rtt, true);
        let t = control_message_latency(rtt, false);
        println!("{rtt_ms:>8.1}ms {:>9.2}ms {:>9.2}ms {:>7.1}×", g * 1e3, t * 1e3, t / g);
        assert!(t > g);
    }
    println!("gmp_rpc OK");
}

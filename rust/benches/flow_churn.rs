//! Bench: fluid-network churn — thousands of concurrent flows arriving
//! and departing on the 120-node OCT topology (30 active nodes per site,
//! shared CiscoWave), the load pattern of the Sector/Sphere companion
//! experiments' segment transfers.
//!
//! Two measurements:
//! 1. The reworked slab / per-link-index core at full churn scale
//!    (default 24k transfers, 6k concurrent).
//! 2. The same deterministic schedule, at a reduced scale both cores can
//!    stomach, through [`baseline`] — a faithful copy of the pre-rework
//!    `FlowNet` (per-call `HashMap` water-filling, generation-counter
//!    stale events) — and through the reworked core. Prints the speedup,
//!    asserts it is ≥ 3×, and asserts both cores produce the *same
//!    simulated makespan* (the rework changes data layout and event
//!    lifecycle, not allocation semantics).
//!
//! Env knobs: `OCT_CHURN_FLOWS`, `OCT_CHURN_CONCURRENCY`,
//! `OCT_CHURN_BASELINE_FLOWS`, `OCT_CHURN_BASELINE_CONCURRENCY`,
//! `OCT_CHURN_SKIP_BASELINE=1`.

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::time::Instant;

use oct::net::{FlowNet, LinkId, NodeId, Topology};
use oct::sim::Engine;
use oct::util::json::{obj, Json};
use oct::util::Rng;

struct Job {
    path: Vec<LinkId>,
    bytes: f64,
    cap: f64,
}

struct Stats {
    wall: f64,
    sim: f64,
    events: u64,
    completions: u64,
}

/// The two cores expose the same start/completions surface; the driver is
/// generic so both run the identical schedule.
trait ChurnNet: 'static {
    fn start_flow(
        net: &Rc<RefCell<Self>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap: f64,
        done: Box<dyn FnOnce(&mut Engine)>,
    );
    fn done_count(&self) -> u64;
}

impl ChurnNet for FlowNet {
    fn start_flow(
        net: &Rc<RefCell<Self>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap: f64,
        done: Box<dyn FnOnce(&mut Engine)>,
    ) {
        FlowNet::start(net, eng, path, bytes, cap, done);
    }

    fn done_count(&self) -> u64 {
        self.completions()
    }
}

impl ChurnNet for baseline::FlowNet {
    fn start_flow(
        net: &Rc<RefCell<Self>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap: f64,
        done: Box<dyn FnOnce(&mut Engine)>,
    ) {
        baseline::FlowNet::start(net, eng, path, bytes, cap, done);
    }

    fn done_count(&self) -> u64 {
        self.completions()
    }
}

/// Each completion spawns the chain's next transfer until the shared
/// budget drains — steady-state churn at the initial concurrency.
fn spawn<N: ChurnNet>(
    net: &Rc<RefCell<N>>,
    eng: &mut Engine,
    jobs: &Rc<Vec<Job>>,
    k: usize,
    left: &Rc<Cell<usize>>,
) {
    if left.get() == 0 {
        return;
    }
    left.set(left.get() - 1);
    let job = &jobs[k % jobs.len()];
    let (path, bytes, cap) = (job.path.clone(), job.bytes, job.cap);
    let net2 = net.clone();
    let jobs2 = jobs.clone();
    let left2 = left.clone();
    N::start_flow(
        net,
        eng,
        path,
        bytes,
        cap,
        Box::new(move |e: &mut Engine| {
            spawn(&net2, e, &jobs2, k + 1, &left2);
        }),
    );
}

fn run_churn<N: ChurnNet>(
    net: Rc<RefCell<N>>,
    jobs: &Rc<Vec<Job>>,
    total: usize,
    conc: usize,
) -> Stats {
    let mut eng = Engine::new();
    let left = Rc::new(Cell::new(total));
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = Instant::now();
    for c in 0..conc.min(total) {
        // Stagger chain starting points through the job table so the
        // concurrent mix is diverse but fully deterministic.
        spawn(&net, &mut eng, jobs, c * 17 + 1, &left);
    }
    eng.run();
    Stats {
        wall: t0.elapsed().as_secs_f64(),
        sim: eng.now(),
        events: eng.executed(),
        completions: net.borrow().done_count(),
    }
}

fn make_jobs(topo: &Topology, nodes: &[NodeId], n: usize) -> Vec<Job> {
    let mut rng = Rng::new(0xF10C);
    // Transport caps take a handful of distinct values in reality (one per
    // RTT class × protocol — see `transport::Protocol::rate_cap`), and the
    // water-filling round count tracks the number of *distinct* freeze
    // levels, so the bench mirrors that instead of smearing a continuum.
    let caps = [1.4e6, 4.5e6, 18.0e6, 35.0e6, 6.0e7, 1.03e8, 1.09e8, f64::INFINITY];
    (0..n)
        .map(|_| {
            let src = nodes[rng.gen_range(nodes.len() as u64) as usize];
            let mut dst = src;
            while dst == src {
                dst = nodes[rng.gen_range(nodes.len() as u64) as usize];
            }
            // Segment-sized transfers (1–64 MB).
            let bytes = (1.0 + rng.f64() * 63.0) * 1e6;
            let cap = caps[rng.gen_range(caps.len() as u64) as usize];
            Job { path: topo.path(src, dst), bytes, cap }
        })
        .collect()
}

fn env_or(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Write the machine-readable baseline to `BENCH_flow_churn.json` at the
/// repo root (next to the other BENCH artifacts), so perf work has a
/// comparison point: simulated makespan, churn throughput, and the
/// speedup over the embedded pre-rework core (null when the baseline leg
/// is skipped).
fn write_bench_json(total: usize, conc: usize, s: &Stats, speedup: Option<f64>) {
    let doc = obj(vec![
        ("bench", Json::Str("flow_churn".into())),
        ("transfers", Json::Num(total as f64)),
        ("concurrency", Json::Num(conc as f64)),
        ("makespan_sim_secs", Json::Num(s.sim)),
        ("wall_secs", Json::Num(s.wall)),
        ("flows_per_sec", Json::Num(total as f64 / s.wall.max(1e-9))),
        ("events", Json::Num(s.events as f64)),
        ("speedup_vs_old_core", speedup.map_or(Json::Null, Json::Num)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_flow_churn.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}

fn report(tag: &str, s: &Stats, total: usize) {
    println!(
        "{tag:<28} {:>8.2}s wall  {:>9.0} flows/s  {:>8} events  {:.1}s simulated",
        s.wall,
        total as f64 / s.wall.max(1e-9),
        s.events,
        s.sim,
    );
}

fn main() {
    let total = env_or("OCT_CHURN_FLOWS", 24_000);
    let conc = env_or("OCT_CHURN_CONCURRENCY", 6_000);
    let base_total = env_or("OCT_CHURN_BASELINE_FLOWS", 1_000);
    let base_conc = env_or("OCT_CHURN_BASELINE_CONCURRENCY", 500);
    let skip_baseline = std::env::var("OCT_CHURN_SKIP_BASELINE").is_ok();

    let topo = Topology::oct_2009();
    // The paper's active footprint: 30 of each site's 32 nodes.
    let nodes: Vec<NodeId> =
        topo.racks.iter().flat_map(|r| r.nodes[..30].iter().copied()).collect();
    assert_eq!(nodes.len(), 120);
    let jobs = Rc::new(make_jobs(&topo, &nodes, 512));

    println!("=== flow churn: {total} transfers, {conc} concurrent, {} nodes ===", nodes.len());
    let s = run_churn(FlowNet::new(&topo), &jobs, total, conc);
    report("reworked core", &s, total);
    assert_eq!(s.completions as usize, total, "lost transfers");

    if skip_baseline {
        write_bench_json(total, conc, &s, None);
        println!("baseline comparison skipped (OCT_CHURN_SKIP_BASELINE)");
        return;
    }
    println!(
        "--- baseline comparison: {base_total} transfers, {base_conc} concurrent (identical schedules) ---"
    );
    let s_new = run_churn(FlowNet::new(&topo), &jobs, base_total, base_conc);
    report("reworked core", &s_new, base_total);
    let s_old = run_churn(baseline::FlowNet::new(&topo), &jobs, base_total, base_conc);
    report("pre-rework core", &s_old, base_total);
    assert_eq!(s_new.completions, s_old.completions, "cores disagree on completions");
    assert!(
        (s_new.sim - s_old.sim).abs() <= 1e-6 * s_old.sim.max(1.0),
        "allocation semantics drifted: {} vs {} simulated seconds",
        s_new.sim,
        s_old.sim,
    );
    let speedup = s_old.wall / s_new.wall.max(1e-9);
    write_bench_json(total, conc, &s, Some(speedup));
    println!("speedup: {speedup:.1}× (same simulated makespan: {:.3}s)", s_new.sim);
    assert!(speedup >= 3.0, "rework regressed: only {speedup:.2}× over the HashMap core");
    println!("flow churn OK");
}

/// A faithful copy of the pre-rework fluid core, kept as the bench's
/// measuring stick: `HashMap` flow storage, per-call allocation of the
/// water-filling state, and the generation-counter "stale event" pattern
/// that leaves one dead event in the engine heap per reallocation.
mod baseline {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::rc::Rc;

    use oct::net::{LinkId, Topology};
    use oct::sim::Engine;

    type Callback = Box<dyn FnOnce(&mut Engine)>;

    struct FlowState {
        path: Vec<LinkId>,
        remaining: f64,
        rate: f64,
        cap: f64,
        done: Option<Callback>,
    }

    pub struct FlowNet {
        capacity: Vec<f64>,
        link_rate: Vec<f64>,
        link_bytes: Vec<f64>,
        flows: HashMap<u64, FlowState>,
        next_id: u64,
        last_advance: f64,
        generation: u64,
        completions: u64,
    }

    impl FlowNet {
        pub fn new(topo: &Topology) -> Rc<RefCell<FlowNet>> {
            let capacity: Vec<f64> = topo.links.iter().map(|l| l.capacity).collect();
            let n = capacity.len();
            Rc::new(RefCell::new(FlowNet {
                capacity,
                link_rate: vec![0.0; n],
                link_bytes: vec![0.0; n],
                flows: HashMap::new(),
                next_id: 0,
                last_advance: 0.0,
                generation: 0,
                completions: 0,
            }))
        }

        pub fn completions(&self) -> u64 {
            self.completions
        }

        fn advance(&mut self, now: f64) {
            let dt = now - self.last_advance;
            if dt <= 0.0 {
                return;
            }
            // simlint: allow(SIM001) — per-flow update, no cross-flow order dependence
            for f in self.flows.values_mut() {
                if f.rate > 0.0 {
                    f.remaining = (f.remaining - f.rate * dt).max(0.0);
                }
            }
            for (l, rate) in self.link_rate.iter().enumerate() {
                if *rate > 0.0 {
                    self.link_bytes[l] += rate * dt;
                }
            }
            self.last_advance = now;
        }

        fn reallocate(&mut self) {
            for r in self.link_rate.iter_mut() {
                *r = 0.0;
            }
            if self.flows.is_empty() {
                return;
            }
            let mut remaining_cap = self.capacity.clone();
            // simlint: allow(SIM001) — collected then sorted before any effect
            let mut ids: Vec<u64> = self.flows.keys().copied().collect();
            ids.sort_unstable();
            let mut rate: HashMap<u64, f64> = ids.iter().map(|&i| (i, 0.0)).collect();
            let mut frozen: HashMap<u64, bool> = ids.iter().map(|&i| (i, false)).collect();
            let mut users: Vec<u32> = vec![0; self.capacity.len()];

            let link_eps = |cap: f64| cap * 1e-9 + 1e-9;
            let max_iters = ids.len() + self.capacity.len() + 8;
            let mut iters = 0usize;
            loop {
                iters += 1;
                for u in users.iter_mut() {
                    *u = 0;
                }
                let mut any = false;
                for &id in &ids {
                    if !frozen[&id] {
                        any = true;
                        for &LinkId(l) in &self.flows[&id].path {
                            users[l] += 1;
                        }
                    }
                }
                if !any {
                    break;
                }
                let mut inc = f64::INFINITY;
                for (l, &u) in users.iter().enumerate() {
                    if u > 0 {
                        inc = inc.min(remaining_cap[l].max(0.0) / u as f64);
                    }
                }
                for &id in &ids {
                    if !frozen[&id] {
                        inc = inc.min(self.flows[&id].cap - rate[&id]);
                    }
                }
                if !inc.is_finite() {
                    break;
                }
                let inc = inc.max(0.0);
                for &id in &ids {
                    if frozen[&id] {
                        continue;
                    }
                    *rate.get_mut(&id).unwrap() += inc;
                    for &LinkId(l) in &self.flows[&id].path {
                        remaining_cap[l] -= inc;
                    }
                }
                let mut froze_any = false;
                for &id in &ids {
                    if frozen[&id] {
                        continue;
                    }
                    let f = &self.flows[&id];
                    let cap_eps =
                        if f.cap.is_finite() { f.cap * 1e-9 + 1e-9 } else { 0.0 };
                    let hit_cap = f.cap.is_finite() && rate[&id] >= f.cap - cap_eps;
                    let hit_link = f
                        .path
                        .iter()
                        .any(|&LinkId(l)| remaining_cap[l] <= link_eps(self.capacity[l]));
                    if hit_cap || hit_link {
                        *frozen.get_mut(&id).unwrap() = true;
                        froze_any = true;
                    }
                }
                if !froze_any || iters >= max_iters {
                    for &id in &ids {
                        *frozen.get_mut(&id).unwrap() = true;
                    }
                    break;
                }
            }

            // simlint: allow(SIM001) — keyed writes; link_rate feeds no scheduling decision
            for (&id, r) in &rate {
                let f = self.flows.get_mut(&id).unwrap();
                f.rate = *r;
                for &LinkId(l) in &f.path {
                    self.link_rate[l] += *r;
                }
            }
        }

        fn next_completion(&self) -> Option<f64> {
            let mut best: Option<f64> = None;
            // simlint: allow(SIM001) — min over f64 is order-insensitive
            for f in self.flows.values() {
                if f.rate > 0.0 {
                    let t = f.remaining / f.rate;
                    best = Some(match best {
                        Some(b) => b.min(t),
                        None => t,
                    });
                }
            }
            best
        }

        pub fn start<F: FnOnce(&mut Engine) + 'static>(
            net: &Rc<RefCell<FlowNet>>,
            eng: &mut Engine,
            path: Vec<LinkId>,
            bytes: f64,
            cap_bps: f64,
            done: F,
        ) {
            assert!(bytes > 0.0 && cap_bps > 0.0);
            assert!(!path.is_empty(), "flow with empty path");
            {
                let mut n = net.borrow_mut();
                n.advance(eng.now());
                let id = n.next_id;
                n.next_id += 1;
                n.flows.insert(
                    id,
                    FlowState {
                        path,
                        remaining: bytes,
                        rate: 0.0,
                        cap: cap_bps,
                        done: Some(Box::new(done)),
                    },
                );
                n.reallocate();
            }
            Self::reschedule(net, eng);
        }

        fn reschedule(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
            let (gen, dt) = {
                let mut n = net.borrow_mut();
                n.generation += 1;
                (n.generation, n.next_completion())
            };
            let Some(dt) = dt else { return };
            let net = net.clone();
            eng.schedule_in(dt.max(0.0), move |eng| {
                if net.borrow().generation != gen {
                    return; // superseded by a later reallocation
                }
                Self::on_completion(&net, eng);
            });
        }

        fn on_completion(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
            let callbacks = {
                let mut n = net.borrow_mut();
                n.advance(eng.now());
                // simlint: allow(SIM001) — collected then sorted before any effect
                let mut finished: Vec<u64> = n
                    .flows
                    .iter()
                    .filter(|(_, f)| f.remaining <= 1e-6 + f.rate * 1e-9)
                    .map(|(&id, _)| id)
                    .collect();
                if finished.is_empty() {
                    if let Some((&id, _)) =
                        // simlint: allow(SIM001) — forced-progress pick; the churn schedule never ties
                        n.flows.iter().filter(|(_, f)| f.rate > 0.0).min_by(|a, b| {
                            let ta = a.1.remaining / a.1.rate;
                            let tb = b.1.remaining / b.1.rate;
                            ta.partial_cmp(&tb).unwrap()
                        })
                    {
                        finished.push(id);
                    }
                }
                let mut cbs = Vec::new();
                let mut ids = finished;
                ids.sort_unstable();
                for id in ids {
                    let mut f = n.flows.remove(&id).unwrap();
                    n.completions += 1;
                    if let Some(cb) = f.done.take() {
                        cbs.push(cb);
                    }
                }
                n.reallocate();
                cbs
            };
            for cb in callbacks {
                cb(eng);
            }
            Self::reschedule(net, eng);
        }
    }
}

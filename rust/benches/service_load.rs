//! Bench: the open-loop service workload through the sharded parallel
//! engine vs sequential execution, over the whole `service` registry set
//! (steady / diurnal / flash-crowd / WAN-degraded / replica ladder).
//!
//! Two assertions, in order of importance:
//!
//! 1. **Byte-identical reports.** The same scaled-down `service` set
//!    runs through the [`ScenarioRunner`] with `--threads 1` and
//!    `--threads N` (default 4). Both take the same sharded driver
//!    (requests are homed at their user's site shard; cross-site
//!    requests ride the WAN shard), so the conservative lookahead
//!    protocol — not luck — must make the per-request latency samples,
//!    quantiles, and SLO counters serialize identically byte for byte.
//!    This always gates.
//! 2. **Wall-clock speedup.** The N-thread run must beat the 1-thread
//!    run by at least `OCT_SERVICE_MIN_SPEEDUP` (default 0 = disabled:
//!    the service scenarios are lighter than the churn storms, so on
//!    small shared runners only the byte-identity check blocks).
//!
//! Writes the machine-readable result to `BENCH_service_load.json` at
//! the repo root, next to the other BENCH artifacts.
//!
//! Env knobs: `OCT_SERVICE_DIV` (divides the registry workload; default
//! 100 → 20k requests per scenario), `OCT_SERVICE_THREADS` (default 4),
//! `OCT_SERVICE_MIN_SPEEDUP` (default 0; 0 disables the speedup gate).

use std::time::Instant;

use oct::coordinator::{find_set, RunReport, ScenarioRunner};
use oct::util::json::{obj, Json};

fn env_or(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_or_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

struct Leg {
    json: String,
    wall: f64,
    reports: Vec<RunReport>,
}

/// One full pass over the set at a fixed thread count. The report JSON
/// deliberately excludes wall-clock stats, so `json` is comparable
/// across legs; the leg's own wall time is measured around the run.
fn run_leg(div: u64, threads: usize) -> Leg {
    let set = find_set("service").expect("service set registered").scaled_down(div);
    let runner = ScenarioRunner::new().with_threads(threads);
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = Instant::now();
    let reports = runner.run_set(&set);
    let wall = t0.elapsed().as_secs_f64();
    let json =
        reports.iter().map(|r| r.to_json().to_string()).collect::<Vec<_>>().join("\n");
    Leg { json, wall, reports }
}

fn write_bench_json(div: u64, threads: u64, seq: &Leg, par: &Leg, speedup: f64) {
    let svc = |r: &RunReport| r.service.clone().expect("service report in service set");
    let requests: u64 = par.reports.iter().map(|r| svc(r).requests).sum();
    let slo_violations: u64 = par.reports.iter().map(|r| svc(r).slo_violations).sum();
    let timeouts: u64 = par.reports.iter().map(|r| svc(r).timeouts).sum();
    let events_per_sec =
        par.reports[0].wall.map_or(Json::Null, |w| Json::Num(w.events_per_sec));
    // The self-profiler's hot-path counters (from the steady scenario)
    // ride along so benchcmp can attribute a wall-time regression;
    // counters are engine-deterministic, the sched ratios host-bound.
    let prof = &par.reports[0].profile;
    let (stalled_rounds, lookahead_util) = match &prof.sched {
        Some(s) => (Json::Num(s.stalled_rounds as f64), Json::Num(s.lookahead_utilization())),
        None => (Json::Null, Json::Null),
    };
    let doc = obj(vec![
        ("bench", Json::Str("service_load".into())),
        ("scale_div", Json::Num(div as f64)),
        ("transfers", Json::Num(requests as f64)),
        ("threads", Json::Num(threads as f64)),
        ("sequential_wall_secs", Json::Num(seq.wall)),
        ("parallel_wall_secs", Json::Num(par.wall)),
        ("speedup_parallel_vs_sequential", Json::Num(speedup)),
        ("events_per_sec_parallel", events_per_sec),
        ("reports_byte_identical", Json::Bool(seq.json == par.json)),
        ("slo_violations", Json::Num(slo_violations as f64)),
        ("timeouts", Json::Num(timeouts as f64)),
        ("steady_p99_ms", Json::Num(svc(&par.reports[0]).p99_ms)),
        ("profile_events", Json::Num(prof.events as f64)),
        ("profile_timers_armed", Json::Num(prof.timers_armed as f64)),
        ("profile_timers_cancelled", Json::Num(prof.timers_cancelled as f64)),
        ("profile_channel_messages", Json::Num(prof.channel_messages as f64)),
        ("profile_refill_components", Json::Num(prof.refill_components as f64)),
        ("profile_dirty_links", Json::Num(prof.dirty_links as f64)),
        ("profile_stalled_rounds", stalled_rounds),
        ("profile_lookahead_utilization", lookahead_util),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_service_load.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}

fn main() {
    let div = env_or("OCT_SERVICE_DIV", 100).max(1);
    let threads = env_or("OCT_SERVICE_THREADS", 4).max(2);
    let min_speedup = env_or_f64("OCT_SERVICE_MIN_SPEEDUP", 0.0);

    println!("=== service load: service registry set at 1/{div} scale ===");
    let seq = run_leg(div, 1);
    println!("sequential (1 thread)    {:>8.2}s wall", seq.wall);
    let par = run_leg(div, threads as usize);
    println!("parallel  ({threads} threads)    {:>8.2}s wall", par.wall);

    // The hard requirement first: any thread count, same bytes.
    assert_eq!(
        seq.json, par.json,
        "sequential and {threads}-thread runs must produce byte-identical reports"
    );
    println!("reports byte-identical across thread counts");

    // The registry's own SLO shape criteria hold (one leg suffices —
    // the reports are byte-identical).
    let set = find_set("service").unwrap().scaled_down(div);
    for c in set.run_checks(&seq.reports) {
        assert!(c.pass, "{}: {}", c.name, c.detail);
    }

    let speedup = seq.wall / par.wall.max(1e-9);
    write_bench_json(div, threads, &seq, &par, speedup);
    println!("speedup: {speedup:.2}× at {threads} threads");
    if min_speedup > 0.0 {
        assert!(
            speedup >= min_speedup,
            "parallel engine too slow: {speedup:.2}× < {min_speedup:.1}× at {threads} threads"
        );
    } else {
        println!("speedup gate disabled (OCT_SERVICE_MIN_SPEEDUP=0)");
    }
    println!("service load OK");
}

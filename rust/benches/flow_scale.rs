//! Bench: flow domains + incremental water-filling at mega-churn scale.
//!
//! Two measurements:
//!
//! 1. **Incremental vs full recompute, bitwise identical.** The
//!    `mega-churn` registry scenario (structured intra-rack pair traffic
//!    plus a thin WAN stream, ~100k concurrent flows at full scale) runs
//!    through the [`ScenarioRunner`] twice: once with incremental
//!    per-component reallocation (the default) and once with
//!    `incremental: false`, which seeds every link and re-fills the whole
//!    network on every event through the same machinery. The two
//!    [`RunReport`]s must serialize to *byte-identical* JSON — the modes
//!    differ only in which clean components they redundantly re-fill to
//!    the same bits — and the incremental run must be ≥ 5× faster. The
//!    one legitimate divergence is the self-profiler's refill/dirty-link
//!    counters (counting redundant re-fills is their job), so the
//!    comparison strips the `profile` object and the bench publishes
//!    both modes' counters instead — the refill ratio is the measured
//!    "why" behind the wall-time speedup.
//!
//! 2. **Semantics vs the pre-refactor core.** The same deterministic
//!    mega-churn-shaped raw schedule runs through [`pre_refactor`] — a
//!    faithful copy of the previous per-flow core (slab + per-link index
//!    lists + `by_cap` order + single cancellable completion timer) whose
//!    `reallocate()` water-fills over **every active flow** on every
//!    arrival and departure — and through the new aggregate core.
//!    Completions must match, makespans agree to 1e-6 relative (the
//!    refactor changes data layout, not allocation semantics), and the
//!    new core must be ≥ 5× faster.
//!
//! Env knobs: `OCT_SCALE_DIV` (divides the registry workload; default 10
//! → 40k transfers / 10k slots; 1 = the full 400k/100k scale),
//! `OCT_SCALE_OLD_FLOWS`, `OCT_SCALE_OLD_CONCURRENCY`,
//! `OCT_SCALE_SKIP_OLD=1`, `OCT_SCALE_MIN_SPEEDUP`.

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::time::Instant;

use oct::coordinator::{find_set, RunReport, ScenarioRunner};
use oct::net::{FlowNet, FlowNetConfig, LinkId, NodeId, Topology};
use oct::sim::Engine;
use oct::util::json::{obj, Json};
use oct::util::Rng;

fn env_or(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

// ---- part 1: the registry scenario, incremental vs full ---------------

struct ModeRun {
    json: String,
    wall: f64,
    reports: Vec<RunReport>,
}

fn run_mode(div: u64, incremental: bool) -> ModeRun {
    let set = find_set("mega-churn").expect("mega-churn set registered").scaled_down(div);
    let runner = ScenarioRunner::new()
        .with_flow_config(FlowNetConfig { aggregate: true, incremental });
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = Instant::now();
    let reports = runner.run_set(&set);
    let wall = t0.elapsed().as_secs_f64();
    // Strip `profile` before the byte-identity comparison: the refill /
    // dirty-link counters legitimately differ between the two modes
    // (that difference IS the optimization being measured); everything
    // else must match bit for bit.
    let json = reports
        .iter()
        .map(|r| {
            let mut j = r.to_json();
            if let Json::Obj(m) = &mut j {
                m.remove("profile");
            }
            j.to_string()
        })
        .collect::<Vec<_>>()
        .join("\n");
    ModeRun { json, wall, reports }
}

// ---- part 2: raw schedule through the old and new cores ---------------

struct Job {
    path: Vec<LinkId>,
    bytes: f64,
    cap: f64,
}

struct Stats {
    wall: f64,
    sim: f64,
    completions: u64,
}

/// Both cores expose the same start/completions surface; the driver is
/// generic so they run the identical deterministic schedule.
trait ScaleNet: 'static {
    fn start_flow(
        net: &Rc<RefCell<Self>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap: f64,
        done: Box<dyn FnOnce(&mut Engine)>,
    );
    fn done_count(&self) -> u64;
}

impl ScaleNet for FlowNet {
    fn start_flow(
        net: &Rc<RefCell<Self>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap: f64,
        done: Box<dyn FnOnce(&mut Engine)>,
    ) {
        FlowNet::start(net, eng, path, bytes, cap, done);
    }

    fn done_count(&self) -> u64 {
        self.completions()
    }
}

impl ScaleNet for pre_refactor::FlowNet {
    fn start_flow(
        net: &Rc<RefCell<Self>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap: f64,
        done: Box<dyn FnOnce(&mut Engine)>,
    ) {
        pre_refactor::FlowNet::start(net, eng, path, bytes, cap, done);
    }

    fn done_count(&self) -> u64 {
        self.completions()
    }
}

/// Each completion relaunches its slot's next job until the shared budget
/// drains — steady-state churn at the initial concurrency.
fn spawn<N: ScaleNet>(
    net: &Rc<RefCell<N>>,
    eng: &mut Engine,
    jobs: &Rc<Vec<Job>>,
    k: usize,
    left: &Rc<Cell<u64>>,
) {
    if left.get() == 0 {
        return;
    }
    left.set(left.get() - 1);
    let job = &jobs[k % jobs.len()];
    let (path, bytes, cap) = (job.path.clone(), job.bytes, job.cap);
    let net2 = net.clone();
    let jobs2 = jobs.clone();
    let left2 = left.clone();
    N::start_flow(
        net,
        eng,
        path,
        bytes,
        cap,
        Box::new(move |e: &mut Engine| {
            spawn(&net2, e, &jobs2, k + 1, &left2);
        }),
    );
}

fn run_schedule<N: ScaleNet>(
    net: Rc<RefCell<N>>,
    jobs: &Rc<Vec<Job>>,
    total: u64,
    conc: u64,
) -> Stats {
    let mut eng = Engine::new();
    let left = Rc::new(Cell::new(total));
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = Instant::now();
    for c in 0..conc.min(total) {
        // Stagger chain starting points through the job table so every
        // pair carries load, deterministically.
        spawn(&net, &mut eng, jobs, (c as usize) * 7 + 1, &left);
    }
    eng.run();
    Stats {
        wall: t0.elapsed().as_secs_f64(),
        sim: eng.now(),
        completions: net.borrow().done_count(),
    }
}

/// Mega-churn-shaped jobs: disjoint intra-rack partner pairs (the first
/// 28 of each rack's first 30 nodes), a thin WAN mix from the leftover
/// pool, and a handful of *discrete* transport caps so the new core's
/// same-path aggregation actually collapses flows.
fn make_jobs(topo: &Topology) -> Vec<Job> {
    let mut rng = Rng::new(0x5CA1E);
    let caps = [1.4e6, 4.5e6, 18.0e6, 6.0e7, 1.09e8, f64::INFINITY];
    let mut pairs: Vec<(NodeId, NodeId)> = Vec::new();
    let mut pool: Vec<NodeId> = Vec::new();
    for r in &topo.racks {
        let active = &r.nodes[..30];
        for c in active[..28].chunks_exact(2) {
            pairs.push((c[0], c[1]));
        }
        pool.extend(&active[28..30]);
    }
    let mut jobs = Vec::new();
    for (i, &(a, b)) in pairs.iter().enumerate() {
        for _ in 0..4 {
            let (src, dst) = if rng.chance(0.5) { (a, b) } else { (b, a) };
            let wan = i % 16 == 15;
            let (src, dst) = if wan {
                let s = pool[rng.gen_range(pool.len() as u64) as usize];
                let mut d = s;
                while d == s {
                    d = pool[rng.gen_range(pool.len() as u64) as usize];
                }
                (s, d)
            } else {
                (src, dst)
            };
            let bytes = (1.0 + rng.f64() * 15.0) * 1e6;
            let cap = caps[rng.gen_range(caps.len() as u64) as usize];
            jobs.push(Job { path: topo.path(src, dst), bytes, cap });
        }
    }
    jobs
}

// ---- reporting --------------------------------------------------------

fn write_bench_json(
    div: u64,
    transfers: u64,
    inc: &ModeRun,
    full: &ModeRun,
    speedup_incremental: f64,
    old_speedup: Option<f64>,
) {
    // Both modes' hot-path counters ride along: the refill ratio
    // (full / incremental) is the structural explanation benchcmp can
    // point at when the wall-time speedup moves.
    let (pi, pf) = (&inc.reports[0].profile, &full.reports[0].profile);
    let doc = obj(vec![
        ("bench", Json::Str("flow_scale".into())),
        ("scale_div", Json::Num(div as f64)),
        ("transfers", Json::Num(transfers as f64)),
        ("incremental_wall_secs", Json::Num(inc.wall)),
        ("full_recompute_wall_secs", Json::Num(full.wall)),
        ("speedup_incremental_vs_full", Json::Num(speedup_incremental)),
        ("reports_byte_identical", Json::Bool(inc.json == full.json)),
        ("speedup_vs_pre_refactor_core", old_speedup.map_or(Json::Null, Json::Num)),
        ("profile_events", Json::Num(pi.events as f64)),
        ("profile_timers_armed", Json::Num(pi.timers_armed as f64)),
        ("profile_refill_components_incremental", Json::Num(pi.refill_components as f64)),
        ("profile_refill_components_full", Json::Num(pf.refill_components as f64)),
        ("profile_dirty_links_incremental", Json::Num(pi.dirty_links as f64)),
        ("profile_dirty_links_full", Json::Num(pf.dirty_links as f64)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_flow_scale.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}

fn main() {
    let div = env_or("OCT_SCALE_DIV", 10).max(1);
    let old_total = env_or("OCT_SCALE_OLD_FLOWS", 4_000);
    let old_conc = env_or("OCT_SCALE_OLD_CONCURRENCY", 2_000);
    let min_speedup = env_or("OCT_SCALE_MIN_SPEEDUP", 5) as f64;
    let skip_old = std::env::var("OCT_SCALE_SKIP_OLD").is_ok();

    println!("=== flow scale: mega-churn registry scenario at 1/{div} scale ===");
    let inc = run_mode(div, true);
    let full = run_mode(div, false);
    let transfers = inc.reports[0].total_records;
    let flows = inc.reports[0].metric("flows").unwrap_or(f64::NAN);
    let peak = inc.reports[0].metric("peak_active").unwrap_or(f64::NAN);
    println!(
        "incremental    {:>8.2}s wall  ({flows:.0} transfers, peak {peak:.0} active)",
        inc.wall
    );
    println!("full recompute {:>8.2}s wall", full.wall);
    assert_eq!(
        inc.json, full.json,
        "incremental and full-recompute runs must produce byte-identical reports"
    );
    let speedup = full.wall / inc.wall.max(1e-9);
    println!("speedup: {speedup:.1}× (reports byte-identical)");
    assert!(
        speedup >= min_speedup,
        "incremental reallocation regressed: only {speedup:.2}× over full recompute"
    );

    // The registry's own shape criteria hold under both modes (one check
    // suffices — the reports are byte-identical).
    let set = find_set("mega-churn").unwrap().scaled_down(div);
    for c in set.run_checks(&inc.reports) {
        assert!(c.pass, "{}: {}", c.name, c.detail);
    }

    if skip_old {
        write_bench_json(div, transfers, &inc, &full, speedup, None);
        println!("pre-refactor comparison skipped (OCT_SCALE_SKIP_OLD)");
        return;
    }

    println!(
        "--- pre-refactor comparison: {old_total} transfers, {old_conc} concurrent (identical schedules) ---"
    );
    let topo = Topology::oct_2009();
    let jobs = Rc::new(make_jobs(&topo));
    let s_new = run_schedule(FlowNet::new(&topo), &jobs, old_total, old_conc);
    println!("aggregate core   {:>8.2}s wall  {:.3}s simulated", s_new.wall, s_new.sim);
    let s_old = run_schedule(pre_refactor::FlowNet::new(&topo), &jobs, old_total, old_conc);
    println!("per-flow core    {:>8.2}s wall  {:.3}s simulated", s_old.wall, s_old.sim);
    assert_eq!(s_new.completions, s_old.completions, "cores disagree on completions");
    assert!(
        (s_new.sim - s_old.sim).abs() <= 1e-6 * s_old.sim.max(1.0),
        "allocation semantics drifted: {} vs {} simulated seconds",
        s_new.sim,
        s_old.sim,
    );
    let old_speedup = s_old.wall / s_new.wall.max(1e-9);
    println!("speedup: {old_speedup:.1}× (same simulated makespan: {:.3}s)", s_new.sim);
    assert!(
        old_speedup >= min_speedup,
        "refactor regressed: only {old_speedup:.2}× over the per-flow global core"
    );
    write_bench_json(div, transfers, &inc, &full, speedup, Some(old_speedup));
    println!("flow scale OK");
}

/// A faithful copy of the pre-refactor fluid core, kept as the bench's
/// measuring stick: per-flow slab storage with per-link index lists and
/// an incrementally-maintained `by_cap` order, a single cancellable
/// completion timer — and a `reallocate()` that water-fills over **every
/// active flow** on every arrival and departure. That global pass is
/// exactly what the flow-domain refactor removes.
mod pre_refactor {
    use std::cell::RefCell;
    use std::cmp::Ordering;
    use std::rc::Rc;

    use oct::net::{LinkId, Topology};
    use oct::sim::{Engine, TimerId};

    type Callback = Box<dyn FnOnce(&mut Engine)>;

    struct FlowState {
        path: Vec<LinkId>,
        remaining: f64,
        rate: f64,
        cap: f64,
        birth: u64,
        active_pos: u32,
        link_pos: Vec<u32>,
        done: Option<Callback>,
    }

    struct Slot {
        state: Option<FlowState>,
    }

    #[derive(Default)]
    struct Scratch {
        remaining: Vec<f64>,
        users: Vec<u32>,
        saturated: Vec<bool>,
        touched: Vec<u32>,
        frozen: Vec<bool>,
    }

    pub struct FlowNet {
        capacity: Vec<f64>,
        link_rate: Vec<f64>,
        link_bytes: Vec<f64>,
        slots: Vec<Slot>,
        free: Vec<u32>,
        active: Vec<u32>,
        by_cap: Vec<u32>,
        link_flows: Vec<Vec<u32>>,
        next_birth: u64,
        last_advance: f64,
        completions: u64,
        timer: Option<TimerId>,
        scratch: Scratch,
    }

    impl FlowNet {
        pub fn new(topo: &Topology) -> Rc<RefCell<FlowNet>> {
            let capacity: Vec<f64> = topo.links.iter().map(|l| l.capacity).collect();
            let n = capacity.len();
            Rc::new(RefCell::new(FlowNet {
                capacity,
                link_rate: vec![0.0; n],
                link_bytes: vec![0.0; n],
                slots: Vec::new(),
                free: Vec::new(),
                active: Vec::new(),
                by_cap: Vec::new(),
                link_flows: vec![Vec::new(); n],
                next_birth: 0,
                last_advance: 0.0,
                completions: 0,
                timer: None,
                scratch: Scratch {
                    remaining: vec![0.0; n],
                    users: vec![0; n],
                    saturated: vec![false; n],
                    ..Scratch::default()
                },
            }))
        }

        pub fn completions(&self) -> u64 {
            self.completions
        }

        fn insert(&mut self, mut state: FlowState) -> u32 {
            state.active_pos = self.active.len() as u32;
            state.link_pos =
                state.path.iter().map(|&LinkId(l)| self.link_flows[l].len() as u32).collect();
            let s = match self.free.pop() {
                Some(s) => {
                    self.slots[s as usize].state = Some(state);
                    s
                }
                None => {
                    self.slots.push(Slot { state: Some(state) });
                    self.scratch.frozen.push(false);
                    (self.slots.len() - 1) as u32
                }
            };
            self.active.push(s);
            let pos = self.by_cap_position(s).unwrap_or_else(|p| p);
            self.by_cap.insert(pos, s);
            for &LinkId(l) in &self.slots[s as usize].state.as_ref().unwrap().path {
                self.link_flows[l].push(s);
            }
            s
        }

        fn by_cap_position(&self, s: u32) -> Result<usize, usize> {
            let cap = self.flow(s).cap;
            self.by_cap.binary_search_by(|&x| {
                let cx = self.flow(x).cap;
                cx.partial_cmp(&cap).unwrap_or(Ordering::Equal).then(x.cmp(&s))
            })
        }

        fn release(&mut self, s: u32) -> FlowState {
            let pos = self.by_cap_position(s).expect("flow missing from cap order");
            self.by_cap.remove(pos);
            let state = self.slots[s as usize].state.take().expect("releasing empty slot");
            self.free.push(s);
            let p = state.active_pos as usize;
            self.active.swap_remove(p);
            if p < self.active.len() {
                let moved = self.active[p];
                self.slots[moved as usize].state.as_mut().unwrap().active_pos = p as u32;
            }
            for (i, &LinkId(l)) in state.path.iter().enumerate() {
                let lf = &mut self.link_flows[l];
                let p = state.link_pos[i] as usize;
                lf.swap_remove(p);
                if p < lf.len() {
                    let moved = lf[p];
                    let old_last = lf.len() as u32;
                    let m = self.slots[moved as usize].state.as_mut().unwrap();
                    for (j, &pl) in m.path.iter().enumerate() {
                        if pl == LinkId(l) && m.link_pos[j] == old_last {
                            m.link_pos[j] = p as u32;
                            break;
                        }
                    }
                }
            }
            state
        }

        fn flow(&self, s: u32) -> &FlowState {
            self.slots[s as usize].state.as_ref().expect("inactive slot")
        }

        fn advance(&mut self, now: f64) {
            let dt = now - self.last_advance;
            if dt <= 0.0 {
                return;
            }
            for &s in &self.active {
                let f = self.slots[s as usize].state.as_mut().unwrap();
                if f.rate > 0.0 {
                    f.remaining = (f.remaining - f.rate * dt).max(0.0);
                }
            }
            for (l, rate) in self.link_rate.iter().enumerate() {
                if *rate > 0.0 {
                    self.link_bytes[l] += rate * dt;
                }
            }
            self.last_advance = now;
        }

        /// The global pass: every call re-fills every active flow.
        fn reallocate(&mut self) {
            for r in self.link_rate.iter_mut() {
                *r = 0.0;
            }
            if self.active.is_empty() {
                return;
            }
            let sc = &mut self.scratch;
            sc.touched.clear();
            for (l, lf) in self.link_flows.iter().enumerate() {
                if !lf.is_empty() {
                    sc.touched.push(l as u32);
                    sc.users[l] = lf.len() as u32;
                    sc.remaining[l] = self.capacity[l];
                    sc.saturated[l] = false;
                }
            }
            for &s in &self.active {
                sc.frozen[s as usize] = false;
            }
            let link_eps = |cap: f64| cap * 1e-9 + 1e-9;
            let cap_eps = |cap: f64| if cap.is_finite() { cap * 1e-9 + 1e-9 } else { 0.0 };
            let mut level = 0.0f64;
            let mut unfrozen = self.active.len();
            let mut cap_ptr = 0usize;
            let max_iters = self.active.len() + sc.touched.len() + 8;
            let mut iters = 0usize;
            while unfrozen > 0 {
                iters += 1;
                let mut inc = f64::INFINITY;
                for &l in &sc.touched {
                    let l = l as usize;
                    if sc.users[l] > 0 {
                        inc = inc.min(sc.remaining[l].max(0.0) / sc.users[l] as f64);
                    }
                }
                while cap_ptr < self.by_cap.len() && sc.frozen[self.by_cap[cap_ptr] as usize] {
                    cap_ptr += 1;
                }
                if cap_ptr < self.by_cap.len() {
                    let cap =
                        self.slots[self.by_cap[cap_ptr] as usize].state.as_ref().unwrap().cap;
                    inc = inc.min(cap - level);
                }
                if !inc.is_finite() {
                    break;
                }
                let inc = inc.max(0.0);
                level += inc;
                for &l in &sc.touched {
                    let l = l as usize;
                    if sc.users[l] > 0 {
                        sc.remaining[l] -= inc * sc.users[l] as f64;
                    }
                }
                let mut froze_any = false;
                while cap_ptr < self.by_cap.len() {
                    let s = self.by_cap[cap_ptr] as usize;
                    if sc.frozen[s] {
                        cap_ptr += 1;
                        continue;
                    }
                    let f = self.slots[s].state.as_mut().unwrap();
                    if f.cap.is_finite() && level >= f.cap - cap_eps(f.cap) {
                        f.rate = level;
                        for &LinkId(l) in &f.path {
                            sc.users[l] -= 1;
                        }
                        sc.frozen[s] = true;
                        froze_any = true;
                        unfrozen -= 1;
                        cap_ptr += 1;
                    } else {
                        break;
                    }
                }
                for &l in &sc.touched {
                    let l = l as usize;
                    if sc.saturated[l] || sc.remaining[l] > link_eps(self.capacity[l]) {
                        continue;
                    }
                    sc.saturated[l] = true;
                    for &s in &self.link_flows[l] {
                        let s = s as usize;
                        if sc.frozen[s] {
                            continue;
                        }
                        let f = self.slots[s].state.as_mut().unwrap();
                        f.rate = level;
                        for &LinkId(pl) in &f.path {
                            sc.users[pl] -= 1;
                        }
                        sc.frozen[s] = true;
                        froze_any = true;
                        unfrozen -= 1;
                    }
                }
                if unfrozen > 0 && (!froze_any || iters >= max_iters) {
                    break;
                }
            }
            if unfrozen > 0 {
                for &s in &self.active {
                    if !sc.frozen[s as usize] {
                        self.slots[s as usize].state.as_mut().unwrap().rate = level;
                    }
                }
            }
            for &s in &self.active {
                let f = self.slots[s as usize].state.as_ref().unwrap();
                for &LinkId(l) in &f.path {
                    self.link_rate[l] += f.rate;
                }
            }
        }

        fn next_completion(&self) -> Option<f64> {
            let mut best: Option<f64> = None;
            for &s in &self.active {
                let f = self.flow(s);
                if f.rate > 0.0 {
                    let t = f.remaining / f.rate;
                    best = Some(match best {
                        Some(b) => b.min(t),
                        None => t,
                    });
                }
            }
            best
        }

        pub fn start<F: FnOnce(&mut Engine) + 'static>(
            net: &Rc<RefCell<FlowNet>>,
            eng: &mut Engine,
            path: Vec<LinkId>,
            bytes: f64,
            cap_bps: f64,
            done: F,
        ) {
            assert!(bytes > 0.0 && cap_bps > 0.0);
            assert!(!path.is_empty(), "flow with empty path");
            {
                let mut n = net.borrow_mut();
                n.advance(eng.now());
                let birth = n.next_birth;
                n.next_birth += 1;
                n.insert(FlowState {
                    path,
                    remaining: bytes,
                    rate: 0.0,
                    cap: cap_bps,
                    birth,
                    active_pos: 0, // assigned by insert
                    link_pos: Vec::new(),
                    done: Some(Box::new(done)),
                });
                n.reallocate();
            }
            Self::reschedule(net, eng);
        }

        fn reschedule(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
            let (old, dt) = {
                let mut n = net.borrow_mut();
                (n.timer.take(), n.next_completion())
            };
            if let Some(t) = old {
                eng.cancel(t);
            }
            let Some(dt) = dt else { return };
            let net2 = net.clone();
            let id = eng.schedule_in(dt.max(0.0), move |eng| {
                Self::on_completion(&net2, eng);
            });
            net.borrow_mut().timer = Some(id);
        }

        fn on_completion(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
            let callbacks = {
                let mut n = net.borrow_mut();
                n.timer = None;
                n.advance(eng.now());
                let mut finished: Vec<u32> = Vec::new();
                for &s in &n.active {
                    let f = n.flow(s);
                    if f.remaining <= 1e-6 + f.rate * 1e-9 {
                        finished.push(s);
                    }
                }
                if finished.is_empty() {
                    let mut best: Option<(f64, u64, u32)> = None;
                    for &s in &n.active {
                        let f = n.flow(s);
                        if f.rate > 0.0 {
                            let t = f.remaining / f.rate;
                            let better = match best {
                                None => true,
                                Some((bt, bb, _)) => t < bt || (t == bt && f.birth < bb),
                            };
                            if better {
                                best = Some((t, f.birth, s));
                            }
                        }
                    }
                    if let Some((_, _, s)) = best {
                        finished.push(s);
                    }
                }
                finished.sort_unstable_by_key(|&s| n.flow(s).birth);
                let mut cbs = Vec::with_capacity(finished.len());
                for s in finished {
                    let mut f = n.release(s);
                    n.completions += 1;
                    if let Some(cb) = f.done.take() {
                        cbs.push(cb);
                    }
                }
                n.reallocate();
                cbs
            };
            for cb in callbacks {
                cb(eng);
            }
            Self::reschedule(net, eng);
        }
    }
}

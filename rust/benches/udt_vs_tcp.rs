//! Bench: UDT vs TCP over the wide area — the §6 mechanism behind
//! Table 2 ("UDT … performs significantly better than TCP over wide area
//! networks"). Sweeps RTT and loss through the transport models *and*
//! measures end-to-end transfer times through the fluid network.

use oct::net::{Cluster, Topology};
use oct::sim::Engine;
use oct::transport::{send, Protocol};
use std::cell::RefCell;
use std::rc::Rc;

fn main() {
    println!("=== per-flow sustained rate vs RTT (bottleneck 1.25 GB/s wave) ===");
    println!("{:>8} {:>14} {:>14} {:>9}", "RTT", "TCP", "UDT", "UDT/TCP");
    let (tcp, udt) = (Protocol::tcp(), Protocol::udt());
    for rtt_ms in [0.1, 1.0, 5.0, 10.0, 22.0, 58.0, 75.0, 100.0] {
        let rtt = rtt_ms / 1e3;
        let t = tcp.rate_cap(rtt, 1.25e9);
        let u = udt.rate_cap(rtt, 1.25e9);
        println!("{:>6.1}ms {:>11.2} MB/s {:>10.1} MB/s {:>8.1}×", rtt_ms, t / 1e6, u / 1e6, u / t);
    }

    println!("\n=== 1 GB node-to-node transfer times on the OCT testbed ===");
    println!("{:>28} {:>12} {:>12}", "path", "TCP", "UDT");
    let topo = Topology::oct_2009();
    let pairs = [
        ("intra-rack", topo.racks[0].nodes[0], topo.racks[0].nodes[1]),
        ("StarLight→UIC (1ms)", topo.racks[1].nodes[0], topo.racks[2].nodes[0]),
        ("JHU→StarLight (22ms)", topo.racks[0].nodes[0], topo.racks[1].nodes[0]),
        ("UIC→UCSD (58ms)", topo.racks[2].nodes[0], topo.racks[3].nodes[0]),
    ];
    for (name, a, b) in pairs {
        let mut times = Vec::new();
        for proto in [Protocol::tcp(), Protocol::udt()] {
            let cluster = Cluster::new(Topology::oct_2009());
            let mut eng = Engine::new();
            let done = Rc::new(RefCell::new(0.0));
            let d = done.clone();
            send(&cluster.net, &cluster.topo, &mut eng, a, b, 1e9, &proto, move |e| {
                *d.borrow_mut() = e.now();
            });
            eng.run();
            times.push(*done.borrow());
        }
        println!("{:>28} {:>11.1}s {:>11.1}s", name, times[0], times[1]);
        assert!(times[1] <= times[0] * 1.1, "{name}: UDT must not lose");
    }
    println!("\nudt_vs_tcp shape OK (UDT ≥ TCP everywhere, ≫ on high-RTT paths)");
}

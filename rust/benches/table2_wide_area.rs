//! Bench: regenerate **Table 2** — the wide-area penalty: 28 nodes in one
//! site vs 7×4 across the testbed, Hadoop (3 and 1 replicas) vs Sector.
//!
//! `OCT_BENCH_SCALE` divides the 15B-record workload (default 20).
//! Asserts the paper's shape: Hadoop pays a large penalty (3-replica
//! worst), Sector's is negligible.

use oct::coordinator::experiment::{format_table2, run_table2};

fn main() {
    let scale: u64 = std::env::var("OCT_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let t0 = std::time::Instant::now();
    let rows = run_table2(scale);
    let wall = t0.elapsed().as_secs_f64();
    println!("=== Table 2: local vs distributed (scale 1/{scale}) ===");
    print!("{}", format_table2(&rows));
    println!("simulated in {wall:.1}s wall");

    let (r3, r1, sec) = (&rows[0], &rows[1], &rows[2]);
    assert!(r3.penalty() > 0.15, "hadoop 3-replica penalty lost: {}", r3.penalty());
    assert!(r1.penalty() > 0.04, "hadoop 1-replica penalty lost: {}", r1.penalty());
    assert!(sec.penalty().abs() < 0.06, "sector penalty out of band: {}", sec.penalty());
    assert!(r1.local_secs < r3.local_secs && r1.dist_secs < r3.dist_secs);
    assert!(sec.dist_secs < r1.dist_secs, "sector must win outright");
    println!(
        "penalties — hadoop r3 {:+.1}% (paper +34.1%), r1 {:+.1}% (paper +31.5%), sector {:+.1}% (paper +4.8%)",
        r3.penalty() * 100.0,
        r1.penalty() * 100.0,
        sec.penalty() * 100.0
    );
    println!("table2 shape OK");
}

//! Bench: regenerate **Table 2** — the wide-area penalty: 28 nodes in one
//! site vs 7×4 across the testbed, Hadoop (3 and 1 replicas) vs Sector —
//! via the scenario registry and `ScenarioRunner`.
//!
//! `OCT_BENCH_SCALE` divides the 15B-record workload (default 20).
//! Asserts the set's shape checks: Hadoop pays a large penalty
//! (3-replica worst), Sector's is negligible.

use oct::coordinator::{find_set, format_checks, format_reports, wide_area_penalty, ScenarioRunner};

fn main() {
    let scale: u64 =
        std::env::var("OCT_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let set = find_set("table2").expect("table2 set registered").scaled_down(scale);
    // simlint: allow(SIM002) — wall-clock times the bench, never steers the simulation
    let t0 = std::time::Instant::now();
    let reports = ScenarioRunner::new().run_all(&set.scenarios);
    let wall = t0.elapsed().as_secs_f64();
    println!("=== Table 2: local vs distributed (scale 1/{scale}) ===");
    print!("{}", format_reports(&reports));
    println!("simulated in {wall:.1}s wall");

    let checks = set.run_checks(&reports);
    print!("{}", format_checks(&checks));
    // Pair reports by the fields they carry rather than by position, so
    // registry reordering cannot silently mislabel the penalties.
    let pen = |fw: &str| {
        let find = |tag: &str| {
            reports
                .iter()
                .find(|r| r.framework == fw && r.scenario.contains(tag))
                .unwrap_or_else(|| panic!("missing report {fw}{tag}"))
        };
        wide_area_penalty(find("/local"), find("/dist")) * 100.0
    };
    println!(
        "penalties — hadoop r3 {:+.1}% (paper +34.1%), r1 {:+.1}% (paper +31.5%), sector {:+.1}% (paper +4.8%)",
        pen("hadoop-mapreduce"),
        pen("hadoop-mapreduce-r1"),
        pen("sector-sphere"),
    );
    assert!(checks.iter().all(|c| c.pass), "table2 shape lost:\n{}", format_checks(&checks));
    println!("table2 shape OK");
}

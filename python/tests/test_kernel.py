"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Counts are integers stored in f32, so comparisons are exact (tolerance 0)
up to 2^24 events per (site, week) cell — far above anything these tests
generate. hypothesis sweeps record counts, plane geometry, bucket
distributions, padding patterns, and the in-kernel matmul operand dtype.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.malstone_hist import malstone_hist
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

# CI-friendly hypothesis profile: interpret-mode pallas is slow, keep cases small.
hypothesis.settings.register_profile(
    "oct", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("oct")


def make_records(rng, n, num_sites, num_weeks, pad_frac=0.1):
    site = rng.integers(0, num_sites, size=n).astype(np.int32)
    week = rng.integers(0, num_weeks, size=n).astype(np.int32)
    marked = (rng.random(n) < 0.3).astype(np.float32)
    pad = rng.random(n) < pad_frac
    site[pad] = -1
    return site, week, marked


def run_both(site, week, marked, num_sites, num_weeks, tile, acc_dtype=jnp.float32):
    comp_k, tot_k = malstone_hist(
        jnp.asarray(site), jnp.asarray(week), jnp.asarray(marked),
        num_sites=num_sites, num_weeks=num_weeks, tile=tile,
        acc_dtype=acc_dtype)
    comp_r, tot_r = ref.hist_ref(
        jnp.asarray(site), jnp.asarray(week), jnp.asarray(marked),
        num_sites, num_weeks)
    return (np.asarray(comp_k), np.asarray(tot_k),
            np.asarray(comp_r), np.asarray(tot_r))


class TestHistBasics:
    def test_single_record(self):
        site = np.array([3], dtype=np.int32)
        week = np.array([5], dtype=np.int32)
        marked = np.array([1.0], dtype=np.float32)
        ck, tk, cr, tr = run_both(site, week, marked, 8, 8, tile=1)
        assert ck[3, 5] == 1.0 and tk[3, 5] == 1.0
        assert ck.sum() == 1.0 and tk.sum() == 1.0
        np.testing.assert_array_equal(ck, cr)
        np.testing.assert_array_equal(tk, tr)

    def test_all_padding(self):
        site = np.full(16, -1, dtype=np.int32)
        week = np.zeros(16, dtype=np.int32)
        marked = np.ones(16, dtype=np.float32)
        ck, tk, _, _ = run_both(site, week, marked, 4, 4, tile=8)
        assert ck.sum() == 0.0 and tk.sum() == 0.0

    def test_unmarked_records_count_total_only(self):
        site = np.zeros(8, dtype=np.int32)
        week = np.zeros(8, dtype=np.int32)
        marked = np.zeros(8, dtype=np.float32)
        ck, tk, _, _ = run_both(site, week, marked, 4, 4, tile=8)
        assert ck[0, 0] == 0.0 and tk[0, 0] == 8.0

    def test_multi_tile_accumulation(self):
        rng = np.random.default_rng(0)
        site, week, marked = make_records(rng, 4 * 32, 16, 8)
        ck, tk, cr, tr = run_both(site, week, marked, 16, 8, tile=32)
        np.testing.assert_array_equal(ck, cr)
        np.testing.assert_array_equal(tk, tr)

    def test_tile_mismatch_raises(self):
        site = np.zeros(10, dtype=np.int32)
        week = np.zeros(10, dtype=np.int32)
        marked = np.zeros(10, dtype=np.float32)
        with pytest.raises(ValueError, match="not a multiple"):
            malstone_hist(jnp.asarray(site), jnp.asarray(week),
                          jnp.asarray(marked), num_sites=4, num_weeks=4,
                          tile=4)

    def test_total_conservation(self):
        """Σ tot == number of valid records; Σ comp == number marked&valid."""
        rng = np.random.default_rng(1)
        site, week, marked = make_records(rng, 256, 32, 16)
        ck, tk, _, _ = run_both(site, week, marked, 32, 16, tile=64)
        valid = site >= 0
        assert tk.sum() == valid.sum()
        assert ck.sum() == (marked[valid] == 1.0).sum()


class TestHistHypothesis:
    @hypothesis.given(
        tiles=st.integers(1, 4),
        tile=st.sampled_from([8, 32, 128]),
        num_sites=st.sampled_from([4, 16, 256]),
        num_weeks=st.sampled_from([4, 8, 64]),
        seed=st.integers(0, 2**31 - 1),
        pad_frac=st.sampled_from([0.0, 0.15, 1.0]),
    )
    def test_kernel_matches_ref(self, tiles, tile, num_sites, num_weeks,
                                seed, pad_frac):
        rng = np.random.default_rng(seed)
        site, week, marked = make_records(rng, tiles * tile, num_sites,
                                          num_weeks, pad_frac)
        ck, tk, cr, tr = run_both(site, week, marked, num_sites, num_weeks,
                                  tile)
        np.testing.assert_allclose(ck, cr, atol=0)
        np.testing.assert_allclose(tk, tr, atol=0)

    @hypothesis.given(
        seed=st.integers(0, 2**31 - 1),
        acc=st.sampled_from(["float32", "bfloat16"]),
    )
    def test_acc_dtype_exact_for_counts(self, seed, acc):
        """bf16 one-hot operands with f32 accumulation stay exact."""
        rng = np.random.default_rng(seed)
        site, week, marked = make_records(rng, 128, 16, 8)
        ck, tk, cr, tr = run_both(site, week, marked, 16, 8, tile=64,
                                  acc_dtype=jnp.dtype(acc))
        np.testing.assert_array_equal(ck, cr)
        np.testing.assert_array_equal(tk, tr)

    @hypothesis.given(seed=st.integers(0, 2**31 - 1),
                      parts=st.integers(2, 5))
    def test_partial_histogram_merge(self, seed, parts):
        """Distributed decomposition: Σ of per-worker planes == global plane."""
        rng = np.random.default_rng(seed)
        tile, num_sites, num_weeks = 32, 16, 8
        site, week, marked = make_records(rng, parts * tile, num_sites,
                                          num_weeks)
        # global
        cg, tg, _, _ = run_both(site, week, marked, num_sites, num_weeks, tile)
        # per-worker partials summed
        cs = np.zeros_like(cg)
        ts = np.zeros_like(tg)
        for p in range(parts):
            sl = slice(p * tile, (p + 1) * tile)
            ck, tk, _, _ = run_both(site[sl], week[sl], marked[sl],
                                    num_sites, num_weeks, tile)
            cs += ck
            ts += tk
        np.testing.assert_array_equal(cs, cg)
        np.testing.assert_array_equal(ts, tg)

"""L2 model tests: ratio graph semantics and end-to-end hist→ratio dataflow."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

hypothesis.settings.register_profile(
    "oct", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("oct")


class TestRatioSemantics:
    def test_ratio_a_simple(self):
        comp = jnp.zeros((4, 4)).at[1, 0].set(2.0).at[1, 3].set(1.0)
        tot = jnp.zeros((4, 4)).at[1, 0].set(4.0).at[1, 3].set(2.0)
        r = np.asarray(ref.ratio_a_ref(comp, tot))
        assert r[1] == 0.5  # (2+1)/(4+2)
        assert (r[[0, 2, 3]] == 0).all()

    def test_ratio_b_cumulative(self):
        comp = jnp.zeros((2, 3)).at[0, 0].set(1.0)
        tot = jnp.zeros((2, 3)).at[0, 0].set(2.0).at[0, 2].set(2.0)
        r = np.asarray(ref.ratio_b_ref(comp, tot))
        np.testing.assert_allclose(r[0], [0.5, 0.5, 0.25])
        np.testing.assert_allclose(r[1], [0.0, 0.0, 0.0])

    def test_empty_sites_zero_not_nan(self):
        z = jnp.zeros((8, 8))
        ra = np.asarray(ref.ratio_a_ref(z, z))
        rb = np.asarray(ref.ratio_b_ref(z, z))
        assert np.isfinite(ra).all() and (ra == 0).all()
        assert np.isfinite(rb).all() and (rb == 0).all()

    @hypothesis.given(seed=st.integers(0, 2**31 - 1))
    def test_ratio_bounds(self, seed):
        """Ratios are always in [0, 1] when comp <= tot (counts)."""
        rng = np.random.default_rng(seed)
        tot = rng.integers(0, 50, size=(16, 8)).astype(np.float32)
        comp = np.minimum(rng.integers(0, 50, size=(16, 8)), tot).astype(np.float32)
        ra = np.asarray(ref.ratio_a_ref(jnp.asarray(comp), jnp.asarray(tot)))
        rb = np.asarray(ref.ratio_b_ref(jnp.asarray(comp), jnp.asarray(tot)))
        assert (ra >= 0).all() and (ra <= 1).all()
        assert (rb >= 0).all() and (rb <= 1).all()

    def test_ratio_b_last_week_equals_ratio_a(self):
        """Cumulative ratio at the final week == overall (A) ratio."""
        rng = np.random.default_rng(7)
        tot = rng.integers(0, 20, size=(32, 16)).astype(np.float32)
        comp = np.minimum(rng.integers(0, 20, size=(32, 16)), tot).astype(np.float32)
        ra = np.asarray(ref.ratio_a_ref(jnp.asarray(comp), jnp.asarray(tot)))
        rb = np.asarray(ref.ratio_b_ref(jnp.asarray(comp), jnp.asarray(tot)))
        np.testing.assert_allclose(rb[:, -1], ra, rtol=1e-6)


class TestModelEntryPoints:
    def test_hist_default_geometry(self):
        rng = np.random.default_rng(3)
        n = model.BATCH
        site = rng.integers(-1, model.NUM_SITES, size=n).astype(np.int32)
        week = rng.integers(0, model.NUM_WEEKS, size=n).astype(np.int32)
        marked = (rng.random(n) < 0.2).astype(np.float32)
        comp, tot = model.hist(jnp.asarray(site), jnp.asarray(week),
                               jnp.asarray(marked))
        cr, tr = ref.hist_ref(jnp.asarray(site), jnp.asarray(week),
                              jnp.asarray(marked), model.NUM_SITES,
                              model.NUM_WEEKS)
        np.testing.assert_array_equal(np.asarray(comp), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(tot), np.asarray(tr))

    def test_entry_points_return_tuples(self):
        p = jnp.ones((model.NUM_SITES, model.NUM_WEEKS))
        assert isinstance(model.ratio_a(p, p), tuple)
        assert isinstance(model.ratio_b(p, p), tuple)


class TestAotLowering:
    def test_lower_all_produces_hlo_text(self):
        from compile import aot
        texts = aot.lower_all()
        assert set(texts) == {"malstone_hist", "malstone_ratio_a",
                              "malstone_ratio_b"}
        for name, text in texts.items():
            assert "HloModule" in text, name
            # tuple return for the rust loader's to_tuple()
            assert "ROOT" in text, name

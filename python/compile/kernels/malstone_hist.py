"""L1 Pallas kernel: MalStone (site, week) histogram as one-hot matmul.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on a GPU this
aggregation is a global-memory atomic scatter-add — one ``atomicAdd`` per
record into ``counts[site][week]``. TPUs have no fast scatter and the MXU
wants dense matmuls, so the kernel re-expresses the histogram as

    counts[S, W] += onehot(site)ᵀ  @  (onehot(week) ⊙ weight[:, None])
                     (S × N)            (N × W)

i.e. one ``S×N×W`` matmul per record tile per output plane. The one-hot
matrices are built in-register from broadcasted-iota compares and never
touch HBM; the two ``[S, W]`` accumulators live in the output VMEM block
across all grid steps (every step maps to block (0, 0)).

BlockSpec schedule: the grid iterates over record tiles of ``tile`` rows;
each step streams ``site/week/marked`` tiles HBM→VMEM (3 × tile × 4 B ≈
48 KiB at tile=4096) while the accumulators (2 × S × W × 4 B = 128 KiB at
S=256, W=64) stay resident. Executed with ``interpret=True`` — real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.

Padding records are flagged with ``site == -1`` and contribute nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(site_ref, week_ref, marked_ref, comp_ref, tot_ref, *,
                 num_sites: int, num_weeks: int, acc_dtype):
    """One grid step: accumulate one record tile into the [S, W] planes."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        comp_ref[...] = jnp.zeros_like(comp_ref)
        tot_ref[...] = jnp.zeros_like(tot_ref)

    site = site_ref[...]  # i32[tile]
    week = week_ref[...]  # i32[tile]
    marked = marked_ref[...].astype(acc_dtype)  # [tile]

    valid = (site >= 0).astype(acc_dtype)  # [tile]

    # One-hot via broadcasted iota compares; stays in registers/VMEM.
    site_ids = jax.lax.broadcasted_iota(jnp.int32, (num_sites, site.shape[0]), 0)
    oh_site = (site[None, :] == site_ids).astype(acc_dtype)  # [S, tile]
    week_ids = jax.lax.broadcasted_iota(jnp.int32, (week.shape[0], num_weeks), 1)
    oh_week = (week[:, None] == week_ids).astype(acc_dtype)  # [tile, W]

    # Two MXU matmuls: marked-weighted plane and valid-count plane.
    comp_ref[...] += jax.lax.dot(oh_site, oh_week * (marked * valid)[:, None],
                                 preferred_element_type=jnp.float32)
    tot_ref[...] += jax.lax.dot(oh_site, oh_week * valid[:, None],
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_sites", "num_weeks",
                                             "tile", "acc_dtype"))
def malstone_hist(site, week, marked, *, num_sites: int = 256,
                  num_weeks: int = 64, tile: int = 4096,
                  acc_dtype=jnp.float32):
    """Histogram a batch of pre-joined MalStone records.

    Args:
      site: int32[N] site bucket per record, -1 for padding. N % tile == 0.
      week: int32[N] week bucket per record.
      marked: float[N] 1.0 iff the entity is compromised within the window.
      num_sites / num_weeks: output plane dimensions.
      tile: records streamed per grid step.
      acc_dtype: in-kernel operand dtype for the one-hot matmuls (bf16 is
        exact here — one-hots and 0/1 weights are representable — while
        accumulation is always f32 via preferred_element_type).

    Returns:
      (comp, tot): float32[num_sites, num_weeks] planes.
    """
    n = site.shape[0]
    if n % tile != 0:
        raise ValueError(f"record count {n} not a multiple of tile {tile}")
    grid = (n // tile,)
    out_shape = jax.ShapeDtypeStruct((num_sites, num_weeks), jnp.float32)
    kernel = functools.partial(_hist_kernel, num_sites=num_sites,
                               num_weeks=num_weeks, acc_dtype=acc_dtype)
    comp, tot = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((num_sites, num_weeks), lambda i: (0, 0)),
            pl.BlockSpec((num_sites, num_weeks), lambda i: (0, 0)),
        ],
        out_shape=[out_shape, out_shape],
        interpret=True,  # CPU-PJRT cannot execute Mosaic custom-calls.
    )(site, week, marked)
    return comp, tot

"""Pure-jnp reference oracle for the MalStone aggregation kernels.

This is the ground truth the Pallas kernel (malstone_hist.py) and the L2
ratio graphs (model.py) are tested against. It is deliberately the most
direct expression of the computation — a scatter-add — with none of the
one-hot-matmul restructuring the TPU kernel uses.

MalStone semantics (OCC TR-09-01, §5 of the OCT paper): log records are
``(event_id, timestamp, site_id, compromise_flag, entity_id)``. For each
site, compute the fraction of visiting entities that become compromised at
any time within the window after the visit. The *join* between visit
records and entity compromise times is done upstream (it is the
shuffle-heavy part of the distributed engines, see rust/src/malstone); the
kernels here consume pre-joined records where ``marked[i] == 1.0`` iff the
entity of record *i* becomes compromised within the window after the visit.

Inputs (one batch of N records; padding records use ``site == -1``):
  site   : int32[N]   site bucket in [0, S); -1 marks padding
  week   : int32[N]   week bucket in [0, W)
  marked : float[N]   1.0 if the visiting entity is later compromised

Outputs:
  comp : float32[S, W]  number of marked visits per (site, week)
  tot  : float32[S, W]  number of valid visits per (site, week)
"""

from __future__ import annotations

import jax.numpy as jnp


def hist_ref(site, week, marked, num_sites: int, num_weeks: int):
    """Scatter-add reference histogram: the direct (GPU-style) formulation."""
    valid = site >= 0
    # Clamp so padding rows index safely; their weight is zeroed by `valid`.
    s = jnp.clip(site, 0, num_sites - 1)
    w = jnp.clip(week, 0, num_weeks - 1)
    v = valid.astype(jnp.float32)
    m = marked.astype(jnp.float32) * v
    comp = jnp.zeros((num_sites, num_weeks), jnp.float32).at[s, w].add(m)
    tot = jnp.zeros((num_sites, num_weeks), jnp.float32).at[s, w].add(v)
    return comp, tot


def ratio_a_ref(comp, tot):
    """MalStone-A: one overall ratio per site (whole time range)."""
    c = comp.sum(axis=1)
    t = tot.sum(axis=1)
    return jnp.where(t > 0, c / jnp.maximum(t, 1.0), 0.0)


def ratio_b_ref(comp, tot):
    """MalStone-B: cumulative weekly ratio series per site.

    For week w the window is weeks [0, w]; the ratio is marked visits over
    total visits accumulated up to and including w.
    """
    cc = jnp.cumsum(comp, axis=1)
    ct = jnp.cumsum(tot, axis=1)
    return jnp.where(ct > 0, cc / jnp.maximum(ct, 1.0), 0.0)

"""AOT driver: lower the L2 graphs to HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts relative to this file):
  malstone_hist.hlo.txt     hist(site, week, marked) -> (comp, tot)
  malstone_ratio_a.hlo.txt  ratio_a(comp, tot) -> (ratio[S],)
  malstone_ratio_b.hlo.txt  ratio_b(comp, tot) -> (ratio[S,W],)
  meta.json                 artifact geometry consumed by rust/src/runtime

Python runs only here, at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Lower every entry point; returns {artifact_name: hlo_text}."""
    arts = {
        "malstone_hist": jax.jit(model.hist).lower(*model.hist_shapes()),
        "malstone_ratio_a": jax.jit(model.ratio_a).lower(*model.plane_shapes()),
        "malstone_ratio_b": jax.jit(model.ratio_b).lower(*model.plane_shapes()),
    }
    return {name: to_hlo_text(low) for name, low in arts.items()}


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    default_out = os.path.join(here, "..", "..", "artifacts")
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=default_out)
    # Back-compat with `make artifacts` invoking --out <file>: treat the
    # file's directory as out-dir and additionally write that file.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    texts = lower_all()
    for name, text in texts.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "num_sites": model.NUM_SITES,
        "num_weeks": model.NUM_WEEKS,
        "tile": model.TILE,
        "batch": model.BATCH,
        "artifacts": sorted(texts),
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'meta.json')}")

    if args.out:  # legacy single-file target used by the Makefile stamp
        with open(args.out, "w") as f:
            f.write(texts["malstone_hist"])


if __name__ == "__main__":
    main()

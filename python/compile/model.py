"""L2 JAX model: the MalStone dataflow graphs that get AOT-compiled.

Three exported entry points (see aot.py, loaded by rust/src/runtime):

  hist(site, week, marked)      -> (comp[S,W], tot[S,W])      (calls the L1
                                   Pallas kernel; the per-worker hot path)
  ratio_a(comp, tot)            -> ratio[S]                   (MalStone-A)
  ratio_b(comp, tot)            -> ratio[S,W]                 (MalStone-B)

The distributed decomposition mirrors the paper's engines: every Sphere
worker / reduce task streams its local record tiles through ``hist`` and
the master sums the partial ``(comp, tot)`` planes (f32 add is the only
cross-worker reduction) before running a ratio graph once. Summation of
partials is associative/commutative, so worker count and record order do
not change the result — the property tests in python/tests and the Rust
integration tests both rely on this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.malstone_hist import malstone_hist
from compile.kernels import ref

# Default artifact geometry. Rust reads these from artifacts/meta.json
# (written by aot.py); keep in sync with rust/src/runtime defaults.
NUM_SITES = 256
NUM_WEEKS = 64
TILE = 4096
BATCH_TILES = 16
BATCH = TILE * BATCH_TILES  # records consumed per hist execution


def hist(site, week, marked):
    """Per-worker aggregation: one batch of pre-joined records -> planes.

    bf16 matmul operands (exact for one-hot/0-1 values, f32 accumulation)
    double CPU-interpret throughput and are the native MXU dtype — see
    EXPERIMENTS.md §Perf for the measured sweep.
    """
    return malstone_hist(site, week, marked, num_sites=NUM_SITES,
                         num_weeks=NUM_WEEKS, tile=TILE,
                         acc_dtype=jnp.bfloat16)


def ratio_a(comp, tot):
    """MalStone-A: overall per-site compromise ratio."""
    return (ref.ratio_a_ref(comp, tot),)


def ratio_b(comp, tot):
    """MalStone-B: cumulative weekly per-site ratio series."""
    return (ref.ratio_b_ref(comp, tot),)


def hist_shapes():
    """Example-arg shapes for lowering ``hist``."""
    return (
        jax.ShapeDtypeStruct((BATCH,), jnp.int32),
        jax.ShapeDtypeStruct((BATCH,), jnp.int32),
        jax.ShapeDtypeStruct((BATCH,), jnp.float32),
    )


def plane_shapes():
    """Example-arg shapes for lowering the ratio graphs."""
    p = jax.ShapeDtypeStruct((NUM_SITES, NUM_WEEKS), jnp.float32)
    return (p, p)

//! Table 2 through the scenario registry, plus an RTT ablation showing
//! *why* Hadoop pays the wide-area penalty and Sector doesn't (the §6
//! mechanism).
//!
//! ```bash
//! cargo run --release --example wide_area_penalty [scale]
//! ```

use oct::coordinator::{find_set, format_checks, format_reports, ScenarioRunner};
use oct::transport::Protocol;

fn main() {
    let scale: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    println!("=== Table 2: 28 local nodes vs 7×4 distributed (scale 1/{scale}) ===");
    let set = find_set("table2").expect("table2 set registered").scaled_down(scale);
    let reports = ScenarioRunner::new().run_all(&set.scenarios);
    print!("{}", format_reports(&reports));
    print!("{}", format_checks(&set.run_checks(&reports)));

    println!("\n=== Mechanism: per-flow transport caps vs RTT (NIC bottleneck 117.5 MB/s) ===");
    let tcp = Protocol::tcp();
    let udt = Protocol::udt();
    println!("{:>8} {:>14} {:>14} {:>8}", "RTT", "TCP cap", "UDT cap", "UDT/TCP");
    for rtt_ms in [0.1, 1.0, 10.0, 22.0, 58.0, 75.0, 100.0] {
        let rtt = rtt_ms / 1e3;
        let t = tcp.rate_cap(rtt, 117.5e6);
        let u = udt.rate_cap(rtt, 117.5e6);
        println!("{:>6.1}ms {:>11.1} MB/s {:>11.1} MB/s {:>7.1}×", rtt_ms, t / 1e6, u / 1e6, u / t);
    }
    println!("\nHadoop moves its shuffle and replica pipeline over TCP; Sector moves");
    println!("buckets over UDT. Above ~10 ms the TCP cap collapses, so only the");
    println!("distributed Hadoop runs slow down — Table 2's penalty gap.");
}

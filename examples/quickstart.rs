//! Quickstart: generate MalStone data with MalGen, compute MalStone-A/B
//! through the AOT-compiled JAX/Pallas kernel via PJRT, and cross-check
//! against the pure-Rust oracle.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use oct::malstone::join::{bucketize, compromise_table};
use oct::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
use oct::malstone::oracle::MalstoneResult;
use oct::runtime::{default_artifact_dir, MalstoneKernels};

fn main() -> anyhow::Result<()> {
    // 1. Generate a small real workload (200k records on 4 "nodes").
    let gen = MalGen::new(MalGenConfig::small(42));
    let records = gen.generate_all(4, 50_000);
    println!("MalGen: {} records, {} compromise events",
        records.len(),
        records.iter().filter(|r| r.compromise_flag == 1).count());

    // 2. The entity join + (site, week) bucketing.
    let kernels = MalstoneKernels::load(&default_artifact_dir())?;
    let (s, w) = (kernels.meta.num_sites as u32, kernels.meta.num_weeks as u32);
    let table = compromise_table(&records);
    let joined = bucketize(&records, &table, s, w, SECONDS_PER_WEEK);

    // 3. Aggregate through the compiled Pallas kernel (PJRT).
    let t0 = std::time::Instant::now();
    let planes = kernels.hist(&joined)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("PJRT hist: {} records in {:.1} ms ({:.2}M rec/s, {} kernel calls)",
        joined.len(), dt * 1e3, joined.len() as f64 / dt / 1e6, kernels.hist_calls.borrow());

    // 4. Ratios via the compiled graphs; verify against the oracle.
    let ratio_a = kernels.ratio_a(&planes)?;
    let mut oracle = MalstoneResult::zero(s as usize, w as usize);
    oracle.accumulate(&joined);
    assert_eq!(planes, oracle, "kernel planes diverge from oracle");
    let want = oracle.ratio_a();
    for (g, w) in ratio_a.iter().zip(&want) {
        assert!((*g as f64 - w).abs() < 1e-6);
    }
    println!("kernel == oracle ✓");

    // 5. Report the most-compromising sites (the benchmark's question).
    let mut sites: Vec<(usize, f32)> = ratio_a.iter().copied().enumerate().collect();
    sites.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top compromising site buckets (MalStone-A):");
    for (site, ratio) in sites.iter().take(5) {
        println!("  site {site:>3}  ratio {:.3}  bad={}", ratio, gen.is_bad_site(*site as u32));
    }
    Ok(())
}

//! Quickstart: generate MalStone data with MalGen, compute MalStone-A/B
//! through the AOT-compiled JAX/Pallas kernel via PJRT (when artifacts
//! and the `pjrt` feature are available — the pure-Rust oracle
//! otherwise), and report the most-compromising sites.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use oct::malstone::join::{bucketize, compromise_table};
use oct::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
use oct::malstone::oracle::MalstoneResult;
use oct::runtime::{default_artifact_dir, MalstoneKernels, DEFAULT_GEOMETRY};

fn main() {
    // 1. Generate a small real workload (200k records on 4 "nodes").
    let gen = MalGen::new(MalGenConfig::small(42));
    let records = gen.generate_all(4, 50_000);
    println!("MalGen: {} records, {} compromise events",
        records.len(),
        records.iter().filter(|r| r.compromise_flag == 1).count());

    // 2. The entity join + (site, week) bucketing.
    let kernels = match MalstoneKernels::load(&default_artifact_dir()) {
        Ok(k) => Some(k),
        Err(e) => {
            println!("PJRT kernels unavailable ({e}); using the pure-Rust oracle");
            None
        }
    };
    let (s, w) = kernels
        .as_ref()
        .map(|k| (k.meta.num_sites as u32, k.meta.num_weeks as u32))
        .unwrap_or(DEFAULT_GEOMETRY);
    let table = compromise_table(&records);
    let joined = bucketize(&records, &table, s, w, SECONDS_PER_WEEK);
    let mut oracle = MalstoneResult::zero(s as usize, w as usize);
    oracle.accumulate(&joined);

    // 3. Aggregate + ratios: through the compiled Pallas kernel when we
    //    have one, cross-checked against the oracle.
    let ratio_a: Vec<f64> = match &kernels {
        Some(k) => {
            let t0 = std::time::Instant::now();
            let planes = k.hist(&joined).expect("PJRT hist");
            let dt = t0.elapsed().as_secs_f64();
            println!("PJRT hist: {} records in {:.1} ms ({:.2}M rec/s, {} kernel calls)",
                joined.len(), dt * 1e3, joined.len() as f64 / dt / 1e6, k.hist_calls.borrow());
            assert_eq!(planes, oracle, "kernel planes diverge from oracle");
            let ra = k.ratio_a(&planes).expect("PJRT ratio_a");
            let want = oracle.ratio_a();
            for (g, w) in ra.iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-6);
            }
            println!("kernel == oracle ✓");
            ra.iter().map(|&x| x as f64).collect()
        }
        None => oracle.ratio_a(),
    };

    // 4. Report the most-compromising sites (the benchmark's question).
    let mut sites: Vec<(usize, f64)> = ratio_a.iter().copied().enumerate().collect();
    sites.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top compromising site buckets (MalStone-A):");
    for (site, ratio) in sites.iter().take(5) {
        println!("  site {site:>3}  ratio {:.3}  bad={}", ratio, gen.is_bad_site(*site as u32));
    }
}

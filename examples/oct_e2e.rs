//! End-to-end driver: the full three-layer system on a real workload.
//!
//! 1. MalGen generates a real sharded dataset (default 2M records on 20
//!    simulated nodes — the Table 1 layout at laptop scale).
//! 2. The engines *execute* MalStone for real — Hadoop-MR dataflow,
//!    Sphere dataflow with the pure-Rust aggregator, and (when the
//!    artifacts and the `pjrt` feature are available) Sphere dataflow
//!    with the **AOT JAX/Pallas kernel via PJRT** (L3→runtime→L2→L1) —
//!    and their planes must agree bit-for-bit with the oracle.
//! 3. The same workload is then *simulated at paper scale* through the
//!    scenario registry (Tables 1–2), printing reports and shape checks.
//!
//! ```bash
//! make artifacts && cargo run --release --example oct_e2e [records] [table_scale]
//! ```
//!
//! Output is recorded in EXPERIMENTS.md.

use oct::coordinator::{find_set, format_checks, format_reports, ScenarioRunner};
use oct::hadoop::mapreduce::execute_malstone;
use oct::malstone::join::{bucketize, compromise_table};
use oct::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
use oct::malstone::oracle::MalstoneResult;
use oct::malstone::Record;
use oct::runtime::{default_artifact_dir, MalstoneKernels, DEFAULT_GEOMETRY};
use oct::sector::sphere::{cpu_aggregator, execute_malstone_with};

fn main() {
    let total_records: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2_000_000);
    let table_scale: u64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(50);
    let nodes = 20usize;

    println!("=== OCT end-to-end: {total_records} records across {nodes} MalGen shards ===");
    let gen = MalGen::new(MalGenConfig { num_entities: 200_000, ..MalGenConfig::small(7) });
    let t0 = std::time::Instant::now();
    let shards: Vec<Vec<Record>> = (0..nodes as u64)
        .map(|s| gen.generate_shard(s, nodes as u64, total_records / nodes))
        .collect();
    let gen_dt = t0.elapsed().as_secs_f64();
    println!("[1] malgen: {:.2}s ({:.2}M rec/s)", gen_dt, total_records as f64 / gen_dt / 1e6);

    // Oracle ground truth (kernel geometry when available, defaults else).
    let kernels = match MalstoneKernels::load(&default_artifact_dir()) {
        Ok(k) => Some(k),
        Err(e) => {
            println!("    (PJRT kernels unavailable: {e})");
            None
        }
    };
    let (s, w) = kernels
        .as_ref()
        .map(|k| (k.meta.num_sites as u32, k.meta.num_weeks as u32))
        .unwrap_or(DEFAULT_GEOMETRY);
    let all: Vec<Record> = shards.iter().flatten().copied().collect();
    let t1 = std::time::Instant::now();
    let table = compromise_table(&all);
    let joined = bucketize(&all, &table, s, w, SECONDS_PER_WEEK);
    let mut oracle = MalstoneResult::zero(s as usize, w as usize);
    oracle.accumulate(&joined);
    println!("[2] oracle: {:.2}s (join + aggregate, single machine)", t1.elapsed().as_secs_f64());

    // Hadoop-MR dataflow, real compute.
    let t2 = std::time::Instant::now();
    let mr = execute_malstone(&shards, 2 * nodes, s, w, SECONDS_PER_WEEK);
    let mr_dt = t2.elapsed().as_secs_f64();
    assert_eq!(mr, oracle, "hadoop-MR execute diverged from oracle");
    println!("[3] hadoop-MR execute: {:.2}s ✓ equals oracle", mr_dt);

    // Sphere dataflow, pure-Rust aggregator.
    let t3 = std::time::Instant::now();
    let sphere_cpu =
        execute_malstone_with(&shards, 2 * nodes, s, w, SECONDS_PER_WEEK, cpu_aggregator);
    let sphere_cpu_dt = t3.elapsed().as_secs_f64();
    assert_eq!(sphere_cpu, oracle, "sphere(cpu) diverged from oracle");
    println!("[4] sphere execute (rust aggregator): {:.2}s ✓ equals oracle", sphere_cpu_dt);

    // Sphere dataflow, AOT JAX/Pallas kernel via PJRT — the hot path.
    if let Some(k) = &kernels {
        let t4 = std::time::Instant::now();
        let sphere_k =
            execute_malstone_with(&shards, 2 * nodes, s, w, SECONDS_PER_WEEK, k.aggregator());
        let sphere_k_dt = t4.elapsed().as_secs_f64();
        assert_eq!(sphere_k, oracle, "sphere(pjrt kernel) diverged from oracle");
        println!(
            "[5] sphere execute (PJRT pallas kernel): {:.2}s ✓ equals oracle ({} kernel calls, {:.2}M rec/s through PJRT)",
            sphere_k_dt,
            k.hist_calls.borrow(),
            total_records as f64 / sphere_k_dt / 1e6
        );
        // MalStone-B ratios from the compiled graph, sanity peek.
        let rb = k.ratio_b(&oracle).expect("ratio_b");
        let nonzero = rb.iter().filter(|&&x| x > 0.0).count();
        println!("[6] MalStone-B series: {}×{} plane, {nonzero} nonzero cells", s, w);
    } else {
        let rb = oracle.ratio_b();
        let nonzero = rb.iter().filter(|&&x| x > 0.0).count();
        println!(
            "[5] PJRT kernel path skipped; oracle MalStone-B series: {}×{} plane, {nonzero} nonzero cells",
            s, w
        );
    }

    // Paper-scale simulated evaluation through the scenario registry.
    println!("\n=== Paper-scale simulation (scale 1/{table_scale}) ===");
    let t5 = std::time::Instant::now();
    let runner = ScenarioRunner::new();
    for name in ["table1", "table2"] {
        let set = find_set(name).expect("registered set").scaled_down(table_scale);
        let reports = runner.run_all(&set.scenarios);
        println!("{}", format_reports(&reports));
        print!("{}", format_checks(&set.run_checks(&reports)));
    }
    println!("(simulated in {:.1}s wall)", t5.elapsed().as_secs_f64());
}

use oct::sim::Engine;
use std::time::Instant;
fn main() {
    // Raw event throughput: self-rescheduling chains.
    let mut eng = Engine::new();
    for i in 0..64 { chain(&mut eng, i as f64 * 1e-6, 2_000_000 / 64); }
    let t0 = Instant::now();
    eng.run();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "engine: {} events in {:.2}s = {:.2}M events/s",
        eng.executed(),
        dt,
        eng.executed() as f64 / dt / 1e6
    );
}
fn chain(eng: &mut Engine, t: f64, left: u32) {
    if left == 0 { return; }
    eng.schedule_at(t, move |e| chain(e, t + 1e-6, left - 1));
}

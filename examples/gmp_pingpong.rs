//! GMP over real UDP: ping-pong latency and the paper's §4 claim that a
//! connectionless protocol beats TCP for small control messages.
//!
//! ```bash
//! cargo run --release --example gmp_pingpong [iters]
//! ```

use std::collections::HashMap;
use std::time::{Duration, Instant};

use oct::gmp::rpc::Handler;
use oct::gmp::{GmpConfig, GmpEndpoint, RpcClient, RpcServer};
use oct::transport::control_message_latency;
use oct::util::stats;

fn main() {
    let iters: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2000);

    // Real loopback RPC over GMP.
    let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
    let addr = ep.local_addr();
    let mut handlers: HashMap<String, Handler> = HashMap::new();
    handlers.insert("ping".into(), Box::new(|b: &[u8]| b.to_vec()));
    let _srv = RpcServer::start(ep, handlers);
    let client = RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());

    // Warmup.
    for _ in 0..100 {
        client.call(addr, "ping", b"x", Duration::from_secs(1)).unwrap();
    }
    let mut lat_us = Vec::with_capacity(iters);
    let t0 = Instant::now();
    for _ in 0..iters {
        let t = Instant::now();
        client
            .call(addr, "ping", b"ping-payload-32-bytes-of-control", Duration::from_secs(1))
            .unwrap();
        lat_us.push(t.elapsed().as_secs_f64() * 1e6);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("GMP RPC over real UDP loopback ({iters} round trips):");
    println!("  mean {:.1} µs   p50 {:.1} µs   p99 {:.1} µs   {:.0} rpc/s",
        stats::mean(&lat_us), stats::percentile(&lat_us, 50.0),
        stats::percentile(&lat_us, 99.0), iters as f64 / wall);

    // The §4 model: GMP (connectionless) vs TCP (handshake first) for one
    // small control message across the testbed's real RTTs.
    println!("\nmodeled one-shot control-message delivery (paper §4):");
    println!("{:>22} {:>10} {:>10} {:>8}", "path", "GMP", "TCP", "saving");
    for (name, rtt) in [
        ("same rack", 100e-6),
        ("Chicago–Chicago", 1e-3),
        ("Chicago–Baltimore", 22e-3),
        ("Chicago–San Diego", 58e-3),
        ("Baltimore–San Diego", 75e-3),
    ] {
        let gmp = control_message_latency(rtt, true);
        let tcp = control_message_latency(rtt, false);
        println!("{name:>22} {:>9.2}ms {:>9.2}ms {:>7.1}×", gmp * 1e3, tcp * 1e3, tcp / gmp);
    }
    println!("\nGMP sends data immediately on the shared UDP port; TCP pays the");
    println!("1.5-RTT handshake per connection — a 4× latency gap at any RTT.");
}

//! Figure 3 demo: the monitoring and visualization system watching a
//! MalStone run, with an injected straggler that the detector flags
//! (paper §3 and §8's "one or two nodes with slightly inferior
//! performance").
//!
//! ```bash
//! cargo run --release --example monitor_demo
//! ```

use oct::hadoop::FrameworkParams;
use oct::monitor::heatmap::Metric;
use oct::monitor::{detect_stragglers, render_heatmap, Monitor};
use oct::net::{Cluster, Topology};
use oct::sector::master::{SectorMaster, Segment};
use oct::sector::SphereEngine;
use oct::sim::Engine;

fn main() {
    let cluster = Cluster::new(Topology::oct_2009());
    let topo = cluster.topo.clone();
    let nodes = topo.node_ids();

    // Inject a degraded NIC on one node (a "slightly inferior" machine).
    let victim = topo.racks[2].nodes[13];
    oct::net::FlowNet::set_capacity(
        &cluster.net,
        &mut Engine::new(),
        topo.node(victim).nic_tx,
        30e6,
    );
    println!("injected straggler: {} (NIC degraded to 30 MB/s)", topo.node(victim).name);

    let mut master = SectorMaster::new(topo.clone());
    let seg_records: u64 = 671_088; // 64 MB segments
    let segs: Vec<Segment> = nodes
        .iter()
        .flat_map(|&n| {
            (0..3).map(move |_| Segment { node: n, bytes: seg_records * 100, records: seg_records })
        })
        .collect();
    master.register_file("demo", segs);

    let mut eng = Engine::new();
    let mon = Monitor::new(topo.clone(), 1.0);
    Monitor::install(&mon, &mut eng, &cluster.net, cluster.pools.clone());
    let done = std::rc::Rc::new(std::cell::RefCell::new(None));
    let d = done.clone();
    SphereEngine::simulate(
        &cluster,
        &master,
        &mut eng,
        "demo",
        &nodes,
        FrameworkParams::sphere(),
        true,
        move |_, r| *d.borrow_mut() = Some(r),
    );

    // Advance in 10-simulated-second steps, rendering Figure 3 frames.
    let mut t = 0.0;
    while done.borrow().is_none() && t < 600.0 {
        t += 10.0;
        eng.run_until(t);
        let cpu = mon.borrow().testbed_cpu() * 100.0;
        println!("\n— simulated t = {t:.0}s — (testbed cpu {cpu:.0}%)");
        print!("{}", render_heatmap(&mon.borrow(), Metric::Network, true));
    }
    mon.borrow_mut().disable();
    eng.run();
    if let Some(r) = done.borrow().as_ref() {
        println!("\nrun complete: {:.1}s simulated, {} segments ({} stolen by the load balancer)",
            r.makespan, r.segments, r.stolen_segments);
    }

    // Sector-style per-link aggregate throughput (what spots bad links).
    println!("\nWAN aggregate throughput (last sample):");
    for (label, bps) in mon.borrow().wan_throughput() {
        println!("  {label:<20} {}", oct::util::units::fmt_rate(bps * 8.0));
    }

    // The detector's verdict.
    let reports = detect_stragglers(&mon.borrow(), &topo, 20, 0.7);
    println!("\nstraggler detector ({} flagged):", reports.len());
    for r in &reports {
        println!(
            "  {}  {}: {:.1} MB/s vs cluster median {:.1} MB/s → blacklist candidate",
            topo.node(r.node).name,
            r.metric,
            r.value / 1e6,
            r.cluster_median / 1e6
        );
    }
    // JSON export of the final frame (the web UI's feed).
    let json = mon.borrow().frame_json(eng.now()).to_string();
    println!("\nframe JSON: {} bytes (first 120: {})", json.len(), &json[..120.min(json.len())]);
}
